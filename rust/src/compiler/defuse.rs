//! Def-use chains over IR values, and memory-object discovery.
//!
//! The paper extracts the memory objects (pointer variables) a kernel
//! accesses, then uses LLVM def-use chains of those values to find all
//! related GPU operations (§III-A1). Here a memory object is any value
//! defined by `Malloc`; uses are every op whose operand list mentions it.

use crate::ir::{op_operands, Function, OpId, OpKind, ValueId};
use std::collections::HashMap;

/// Def-use index for one function.
#[derive(Debug)]
pub struct DefUse {
    /// Defining op of each value (params have none).
    pub def: HashMap<ValueId, OpId>,
    /// Ops using each value, in layout order.
    pub uses: HashMap<ValueId, Vec<OpId>>,
    /// Values defined by `Malloc` (the memory objects).
    pub mem_objs: Vec<ValueId>,
}

impl DefUse {
    pub fn build(f: &Function) -> Self {
        let mut def = HashMap::new();
        let mut uses: HashMap<ValueId, Vec<OpId>> = HashMap::new();
        let mut mem_objs = Vec::new();
        for (_, _, op) in f.ops() {
            if let Some(r) = op.result {
                def.insert(r, op.id);
                if matches!(op.kind, OpKind::Malloc { .. }) {
                    mem_objs.push(r);
                }
            }
            for v in op_operands(&op.kind) {
                uses.entry(v).or_default().push(op.id);
            }
        }
        DefUse { def, uses, mem_objs }
    }

    /// The transitive closure of scalar values feeding `v` (for locating
    /// every symbol definition a probe must wait for).
    pub fn scalar_deps(&self, f: &Function, v: ValueId, out: &mut Vec<ValueId>) {
        if out.contains(&v) {
            return;
        }
        out.push(v);
        if let Some(&d) = self.def.get(&v) {
            if let Some((op, _, _)) = f.op(d) {
                for dep in op_operands(&op.kind) {
                    self.scalar_deps(f, dep, out);
                }
            }
        }
    }

    /// All GPU ops related to a memory object: its malloc plus every
    /// memcpy/memset/free/launch that uses it.
    pub fn gpu_ops_of(&self, f: &Function, obj: ValueId) -> Vec<OpId> {
        let mut ops = Vec::new();
        if let Some(&d) = self.def.get(&obj) {
            ops.push(d);
        }
        for &u in self.uses.get(&obj).map(|v| v.as_slice()).unwrap_or(&[]) {
            if let Some((op, _, _)) = f.op(u) {
                match op.kind {
                    OpKind::Memcpy { .. }
                    | OpKind::Memset { .. }
                    | OpKind::Free { .. }
                    | OpKind::Launch { .. } => ops.push(u),
                    _ => {}
                }
            }
        }
        ops.sort_unstable();
        ops.dedup();
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Expr, ProgramBuilder};

    #[test]
    fn mallocs_become_mem_objs_and_uses_chain() {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 1, |f| {
            let n = f.param(0);
            let sz = f.assign(Expr::v(n).mul(Expr::c(4)));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let g = f.assign(Expr::v(n).ceil_div(Expr::c(128)));
            let blk = f.c(256);
            let w = f.c(1000);
            f.launch("k", g, blk, &[a], w);
            f.d2h(a, sz);
            f.free(a);
        });
        let p = pb.finish();
        let f = p.main();
        let du = DefUse::build(f);
        assert_eq!(du.mem_objs.len(), 1);
        let obj = du.mem_objs[0];
        let ops = du.gpu_ops_of(f, obj);
        // malloc, h2d, launch, d2h, free = 5 GPU ops
        assert_eq!(ops.len(), 5);
        // scalar deps of the size value reach the parameter
        let sz_val = obj - 1;
        let mut deps = Vec::new();
        du.scalar_deps(f, sz_val, &mut deps);
        assert!(deps.contains(&0));
    }
}

//! MGB — *Effective GPU Sharing Under Compiler Guidance* (Chen, Porter,
//! Pande; 2021), reproduced as a three-layer Rust + JAX + Pallas system.
//!
//! Layer 3 (this crate) implements the paper's contribution: a compiler
//! pass over a mini-CUDA host IR that constructs **GPU tasks**, a lazy
//! runtime that binds resource needs to tasks, and a user-level scheduler
//! that places tasks onto the devices of a simulated multi-GPU node.
//! Layers 2/1 (JAX models + Pallas kernels, `python/compile/`) are
//! AOT-lowered to HLO text once and executed from Rust via PJRT
//! (`runtime`), so every simulated kernel launch can run real numerics.

pub mod bench_harness;
pub mod compiler;
pub mod coordinator;
pub mod gpu;
pub mod sched;
pub mod workloads;
pub mod lazy;
pub mod ir;
pub mod runtime;

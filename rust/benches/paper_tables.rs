//! `cargo bench --bench paper_tables` — regenerates every table and
//! figure of the paper's evaluation (§V) and times each experiment.
//! The rows themselves are the deliverable; timings show the simulator
//! keeps whole-paper sweeps interactive.

use mgb::bench_harness::{self, time_it, DEFAULT_SEED};

fn main() {
    // `cargo bench` passes --bench; ignore argv beyond a seed override.
    let seed = std::env::args()
        .filter_map(|a| a.parse::<u64>().ok())
        .next()
        .unwrap_or(DEFAULT_SEED);
    println!("== paper experiment regeneration (seed {seed}) ==\n");
    let mut reports = Vec::new();
    for exp in ["fig4", "fig5", "table2", "table3", "fig6", "nn128", "table4"] {
        let mut last = None;
        time_it(&format!("experiment {exp}"), 3, || {
            last = bench_harness::run_experiment(exp, seed);
        });
        reports.push(last.unwrap());
    }
    println!();
    for r in reports {
        r.print();
    }
}

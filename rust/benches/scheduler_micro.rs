//! `cargo bench --bench scheduler_micro` — L3 hot-path
//! micro-benchmarks: placement decision latency (the paper's "very
//! simple to minimize the runtime overheads" claim for Alg. 3 vs the
//! SM-mirroring Alg. 2), compiler pass cost, lazy-runtime interpretation
//! throughput, full batch-simulation wall time, and the fleet-scale
//! `bench scale` sweep (calendar queue vs `BinaryHeap` reference),
//! which rewrites `BENCH_SCALE.json` at the repo root on every run.
//! Set `MGB_SKIP_SCALE=1` to skip the sweep's 1000-node rows locally.

use mgb::bench_harness::time_it;
use mgb::compiler::compile;
use mgb::coordinator::{run_batch, RunConfig, SchedMode};
use mgb::gpu::{GpuSpec, InterferenceProfile, NodeSpec};
use mgb::lazy::interpret;
use mgb::sched::{make_policy, DeviceView, TaskReq};
use mgb::workloads::{Workload, COMBOS};

fn main() {
    println!("== L3 micro-benchmarks ==");

    // -- scheduler decision latency ------------------------------------
    let views: Vec<DeviceView> = (0..4)
        .map(|_| DeviceView { spec: GpuSpec::v100(), free_mem: 8 << 30 })
        .collect();
    let req = TaskReq { mem_bytes: 2 << 30, tbs: 800, warps_per_tb: 4, slo: None, iv: InterferenceProfile::ZERO };
    for name in ["mgb3", "mgb2", "schedgpu"] {
        let mut policy = make_policy(name, 4);
        let mut i = 0usize;
        time_it(&format!("{name} place+release decision"), 20_000, || {
            if let Some(_d) = policy.place((i, 0), &req, &views) {
                policy.release((i, 0));
            }
            i += 1;
        });
    }

    // -- steady-state placement under load (device half full) ----------
    let mut policy = make_policy("mgb2", 4);
    for j in 0..6 {
        policy.place((1_000_000 + j, 0), &req, &views);
    }
    let mut i = 0usize;
    time_it("mgb2 place+release, 6 tasks resident", 20_000, || {
        if policy.place((i, 0), &req, &views).is_some() {
            policy.release((i, 0));
        }
        i += 1;
    });

    // -- compiler pass over every Rodinia program -----------------------
    time_it("compile all 17 rodinia programs", 50, || {
        for c in &COMBOS {
            let _ = compile(&c.program());
        }
    });

    // -- lazy runtime interpretation ------------------------------------
    let compiled: Vec<_> = COMBOS.iter().map(|c| compile(&c.program())).collect();
    time_it("interpret all 17 rodinia traces", 50, || {
        for c in &compiled {
            let _ = interpret(c, &[]).unwrap();
        }
    });

    // -- full batch simulations -----------------------------------------
    let jobs16 = Workload::by_id("W2").unwrap().jobs(1);
    time_it("simulate W2 (16 jobs) under MGB-Alg3", 50, || {
        let _ = run_batch(
            RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 16 },
            jobs16.clone(),
        );
    });
    let jobs128 = mgb::workloads::nn_mix(128, 1);
    // The sim consumes its jobs; the clone below is benchmark overhead —
    // measure it separately so the sim-only time can be read off.
    time_it("(baseline) clone 128 job specs", 20, || {
        let c = jobs128.clone();
        std::hint::black_box(&c);
    });
    time_it("simulate 128-job NN mix under MGB-Alg3", 20, || {
        let _ = run_batch(
            RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 32 },
            jobs128.clone(),
        );
    });

    // -- fleet-scale event-core sweep -----------------------------------
    // Each row runs once per backend (the rows are whole cluster
    // simulations; iterating them criterion-style would take hours).
    // The full sweep also rewrites BENCH_SCALE.json at the repo root —
    // the artifact CI's regression gate compares against.
    println!();
    if std::env::var_os("MGB_SKIP_SCALE").is_some() {
        let r = mgb::bench_harness::scale_smoke_point(mgb::bench_harness::DEFAULT_SEED);
        println!(
            "scale smoke {:<10} events={} peak_events={} heap={:.0}ev/s calendar={:.0}ev/s \
             speedup={:.2}x (MGB_SKIP_SCALE set; BENCH_SCALE.json not rewritten)",
            r.label,
            r.events,
            r.peak_events,
            r.baseline_events_per_s,
            r.events_per_s,
            r.speedup_vs_baseline()
        );
    } else {
        mgb::bench_harness::scale(mgb::bench_harness::DEFAULT_SEED).print();
    }
}

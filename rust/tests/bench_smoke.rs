//! Smoke coverage for the bench harness: every experiment id must run
//! end-to-end without panicking and produce rows, and the latency
//! sweep must show the monotone turnaround growth its report claims.
//! (Before this file only fig4/fig6/nn128/cluster had any coverage.)

use mgb::bench_harness::{self, latency_sweep, sweep_model, RTT_SWEEP};

fn smoke(name: &str) {
    let r = bench_harness::run_experiment(name, 2)
        .unwrap_or_else(|| panic!("experiment '{name}' unknown"));
    assert!(!r.lines.is_empty(), "{name} produced no rows");
    assert!(!r.title.is_empty(), "{name} has no title");
    let text = r.to_string();
    assert!(text.starts_with("== "), "{name}: report header missing");
    assert!(text.lines().count() >= 1 + r.lines.len());
}

#[test]
fn fig5_runs() {
    smoke("fig5");
}

#[test]
fn table2_runs() {
    smoke("table2");
}

#[test]
fn table3_runs() {
    smoke("table3");
}

#[test]
fn table4_runs() {
    smoke("table4");
}

#[test]
fn ablation_runs() {
    smoke("ablation");
}

#[test]
fn preempt_runs() {
    smoke("preempt");
}

#[test]
fn latency_runs() {
    smoke("latency");
}

#[test]
fn unknown_experiment_is_rejected() {
    assert!(bench_harness::run_experiment("latencyy", 2).is_none());
}

#[test]
fn latency_sweep_turnaround_grows_monotonically_with_rtt() {
    // The acceptance criterion for the latency tentpole: on the same
    // open-system stream, mean turnaround must rise monotonically with
    // the probe RTT, and visibly so from the free frontend to the
    // worst swept RTT.
    let rows = latency_sweep(2);
    assert_eq!(rows.len(), RTT_SWEEP.len());
    let mut prev = f64::NEG_INFINITY;
    for (rtt, r) in &rows {
        assert_eq!(r.crashed(), 0, "rtt {rtt}: memory safety is latency-independent");
        assert_eq!(r.completed(), 16, "rtt {rtt}: jobs conserved");
        let mt = r.mean_turnaround();
        assert!(
            mt >= prev - 1e-6,
            "turnaround must not drop as RTT grows: {mt} after {prev} (rtt {rtt})"
        );
        prev = mt;
    }
    let base = rows[0].1.mean_turnaround();
    let worst = rows.last().unwrap().1.mean_turnaround();
    // 2 s RTT per probe on multi-task jobs: the tail of the sweep must
    // sit well above the free-frontend baseline, not within noise.
    assert!(
        worst > base + 2.0,
        "sweep should visibly penalise turnaround: {base} -> {worst}"
    );
}

#[test]
fn sweep_model_is_off_only_at_zero() {
    assert!(sweep_model(0.0).is_off());
    for &rtt in &RTT_SWEEP[1..] {
        let m = sweep_model(rtt);
        assert!(!m.is_off());
        assert_eq!(m.probe_rtt_s, rtt);
        assert!(m.dispatch_base_s > 0.0 && m.frontend_service_s > 0.0);
    }
}

//! Smoke coverage for the bench harness: every experiment id must run
//! end-to-end without panicking and produce rows, and the latency
//! sweep must show the monotone turnaround growth its report claims.
//! (Before this file only fig4/fig6/nn128/cluster had any coverage.)

use mgb::bench_harness::{
    self, latency_dispatch_comparison, latency_sweep, migrate_comparison, reprobe_model,
    sweep_model, MIGRATE_RTT_SWEEP, RTT_SWEEP,
};

fn smoke(name: &str) {
    let r = bench_harness::run_experiment(name, 2)
        .unwrap_or_else(|| panic!("experiment '{name}' unknown"));
    assert!(!r.lines.is_empty(), "{name} produced no rows");
    assert!(!r.title.is_empty(), "{name} has no title");
    let text = r.to_string();
    assert!(text.starts_with("== "), "{name}: report header missing");
    assert!(text.lines().count() >= 1 + r.lines.len());
}

#[test]
fn fig5_runs() {
    smoke("fig5");
}

#[test]
fn table2_runs() {
    smoke("table2");
}

#[test]
fn table3_runs() {
    smoke("table3");
}

#[test]
fn table4_runs() {
    smoke("table4");
}

#[test]
fn ablation_runs() {
    smoke("ablation");
}

#[test]
fn preempt_runs() {
    smoke("preempt");
}

#[test]
fn latency_runs() {
    smoke("latency");
}

#[test]
fn migrate_runs() {
    smoke("migrate");
}

#[test]
fn cluster_restore_never_worsens_turnaround_at_zero_rtt() {
    // The PR acceptance bound: with a free frontend (zero RTT) routing
    // a checkpointed victim's restore through the cluster frontend can
    // only help — the dispatcher may still pick the home node, and any
    // other pick it makes is by its own load ranking. The bench's
    // scenario makes it a strict win (the victim escapes its heavy's
    // 100 s residency), and same-node-only must never migrate at all.
    let rows = migrate_comparison(2);
    assert_eq!(rows.len(), MIGRATE_RTT_SWEEP.len());
    // Export the comparison as a JSON artifact (hand-rolled; the
    // offline crate set has no serde) for CI upload next to the golden
    // traces.
    let mut json = String::from("[\n");
    for (rtt, results) in &rows {
        for (label, r) in results {
            json.push_str(&format!(
                "  {{\"rtt_s\": {rtt}, \"restore\": \"{label}\", \
                 \"mean_turnaround_s\": {:.6}, \"makespan_s\": {:.6}, \
                 \"preemptions\": {}, \"migrations\": {}, \"migrate_bytes\": {}}},\n",
                r.mean_turnaround(),
                r.makespan,
                r.preemptions,
                r.migrations,
                r.migrate_bytes
            ));
        }
    }
    let json = json.trim_end_matches(",\n").to_string() + "\n]\n";
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bench_migrate.json"), json).unwrap();
    for (rtt, results) in &rows {
        let row = |name: &str| {
            &results
                .iter()
                .find(|(n, _)| *n == name)
                .unwrap_or_else(|| panic!("row '{name}' missing at rtt {rtt}"))
                .1
        };
        let (same, cluster) = (row("same-node"), row("cluster"));
        for r in [same, cluster] {
            assert_eq!(r.crashed(), 0, "rtt {rtt}: migration must stay memory-safe");
            assert_eq!(r.completed(), 3, "rtt {rtt}: jobs conserved");
        }
        assert_eq!(same.migrations, 0, "same-node-only restore never migrates");
        assert_eq!(same.migrate_bytes, 0);
        assert_eq!(cluster.migrations, 1, "rtt {rtt}: the evicted hog migrates once");
        assert_eq!(cluster.migrate_bytes, 12 << 30, "the 12 GiB image crossed nodes");
        if *rtt == 0.0 {
            assert!(
                cluster.mean_turnaround() <= same.mean_turnaround() + 1e-9,
                "zero RTT: cluster-wide restore must not worsen mean turnaround \
                 ({} vs {})",
                cluster.mean_turnaround(),
                same.mean_turnaround()
            );
        }
    }
}

#[test]
fn admission_holds_the_knee_at_twice_capacity() {
    // The overload PR's acceptance bound, on the fixed smoke point (a
    // 2-node cluster at 2x measured capacity): the token-bucket
    // frontend must (a) keep latency-sensitive attainment at or above
    // the ungoverned frontend's — governance exists to protect that
    // class — and (b) keep goodput within 5% of the capacity knee:
    // shedding best-effort excess must not cost completions the
    // cluster could have served.
    let (knee, off, token) = bench_harness::overload_smoke(2);
    assert!(knee > 0.0 && knee.is_finite(), "capacity knee: {knee}");
    assert_eq!(
        (off.rejected, off.degraded),
        (0, 0),
        "the ungoverned row never sheds"
    );
    // Latency-sensitive jobs are never rejected, so both attainments
    // are real numbers, not the absent-class NaN.
    assert!(
        off.ls_attainment.is_finite() && token.ls_attainment.is_finite(),
        "LS attainment must be measurable on both rows ({} / {})",
        off.ls_attainment,
        token.ls_attainment
    );
    assert!(
        token.ls_attainment + 1e-12 >= off.ls_attainment,
        "governed LS attainment {} fell below ungoverned {}",
        token.ls_attainment,
        off.ls_attainment
    );
    assert!(
        token.goodput >= 0.95 * knee,
        "governed goodput {} fell more than 5% below the capacity knee {knee}",
        token.goodput
    );
}

#[test]
fn unknown_experiment_is_rejected() {
    assert!(bench_harness::run_experiment("latencyy", 2).is_none());
}

#[test]
fn partition_bounds_worst_case_degradation_under_pressure() {
    // The interference PR's acceptance bound: on the high-pressure
    // small-footprint mix (2 GiB jobs with hot vectors — four fit one
    // half-V100 slice), the partitioned dispatcher's worst per-kernel
    // degradation must not exceed either sharing dispatcher's. Slices
    // are isolation domains, so partitioning halves the worst-case
    // co-residency a kernel can suffer; sharing buys throughput by
    // giving that bound up.
    let rows = bench_harness::hot_mix_comparison(2);
    assert_eq!(rows.len(), 3);
    let row = |d: &str| {
        rows.iter()
            .find(|r| r.dispatch == d)
            .unwrap_or_else(|| panic!("row '{d}' missing"))
    };
    for r in &rows {
        assert_eq!(r.crashed, 0, "{}: the high-pressure mix must stay memory-safe", r.dispatch);
        assert_eq!(r.completed, r.jobs, "{}: jobs conserved", r.dispatch);
        assert!(r.interference, "comparison rows run with vectors on");
    }
    let partition = row("partition").worst_kernel_slowdown_pct;
    for d in ["least", "mem"] {
        let sharing = row(d).worst_kernel_slowdown_pct;
        assert!(
            partition <= sharing + 1e-9,
            "partition worst-case degradation {partition}% must not exceed {d}'s {sharing}%"
        );
    }
    // Export the comparison as a JSON artifact for CI upload next to
    // BENCH_SCALE.json (same hand-rolled-JSON convention).
    let json = bench_harness::bench_interference_json("smoke", 2, &rows);
    assert!(json.contains("\"dispatch\": \"partition\""));
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bench_interference.json"), json).unwrap();
}

#[test]
fn interference_off_rows_reproduce_bench_cluster_numbers() {
    // The zero-vector contract at the report level: `bench
    // --exp interference`'s off rows use the exact `bench cluster` job
    // construction, so their numbers must equal a from-scratch run of
    // that recipe bit for bit — any drift means the interference
    // plumbing perturbed the off path.
    use mgb::coordinator::{run_cluster, ClusterConfig, SchedMode};
    use mgb::gpu::{ClusterSpec, LatencyModel, NodeSpec};
    use mgb::workloads::{poisson_arrivals, Workload};
    let node = NodeSpec::v100x4();
    let w5 = Workload::by_id("W5").expect("W5 exists");
    let mut jobs = Vec::new();
    for k in 0..2u64 {
        jobs.extend(w5.jobs(2u64.wrapping_add(k)));
    }
    poisson_arrivals(&mut jobs, 0.35 * 2.0, 2);
    let r = run_cluster(
        ClusterConfig {
            cluster: ClusterSpec::homogeneous(node.clone(), 2),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: bench_harness::mgb_workers(&node),
            dispatch: "least",
            preempt: None,
            latency: LatencyModel::off(),
            admit: None,
            frontend_q: "fifo",
            compile_traces: false,
        },
        jobs,
    );
    let row = bench_harness::w5_row(2, 2, "least", false);
    assert!(!row.interference);
    assert_eq!(row.jobs, r.jobs.len());
    assert_eq!(row.completed, r.completed());
    assert_eq!(row.crashed, r.crashed());
    assert_eq!(row.throughput, r.throughput(), "throughput must match bit for bit");
    assert_eq!(row.mean_turnaround_s, r.mean_turnaround());
    assert_eq!(row.kernel_slowdown_pct, r.kernel_slowdown_pct());
    assert_eq!(row.worst_kernel_slowdown_pct, r.worst_kernel_slowdown_pct());
}

#[test]
fn scale_smoke_row_holds_the_backend_contract() {
    // The fast row of `bench scale` (the full sweep's 1000-node rows
    // belong to `cargo bench` / CI, not the test suite). `run_point`
    // itself asserts calendar-vs-heap determinism (events, peak,
    // outcomes, makespan); here we pin the row's shape and that the
    // sweep actually exercises an open-system multi-node run.
    let r = bench_harness::scale_smoke_point(2);
    assert_eq!(r.nodes, 2);
    assert_eq!(r.jobs, 64);
    assert!(r.events >= r.jobs as u64, "every job fires at least one event");
    assert!(r.peak_events > 0 && r.peak_events <= r.events as usize);
    assert!(r.events_per_s > 0.0 && r.baseline_events_per_s > 0.0);
    assert!(r.speedup_vs_baseline() > 0.0);
    // And the JSON emitter round-trips the row without structural rot.
    let json = bench_harness::bench_scale_json("smoke", 2, 1.0, &[r]);
    assert!(json.contains("\"label\": \"smoke-2n\""));
    assert_eq!(json.matches('{').count(), json.matches('}').count());
}

#[test]
fn scale_calibration_row_is_positive_and_heap_backed() {
    let c = bench_harness::calibration_events_per_s(2);
    assert!(c > 0.0 && c.is_finite(), "calibration events/sec: {c}");
}

#[test]
fn latency_sweep_turnaround_grows_monotonically_with_rtt() {
    // The acceptance criterion for the latency tentpole: on the same
    // open-system stream, mean turnaround must rise monotonically with
    // the probe RTT, and visibly so from the free frontend to the
    // worst swept RTT.
    let rows = latency_sweep(2);
    assert_eq!(rows.len(), RTT_SWEEP.len());
    let mut prev = f64::NEG_INFINITY;
    for (rtt, r) in &rows {
        assert_eq!(r.crashed(), 0, "rtt {rtt}: memory safety is latency-independent");
        assert_eq!(r.completed(), 16, "rtt {rtt}: jobs conserved");
        let mt = r.mean_turnaround();
        assert!(
            mt >= prev - 1e-6,
            "turnaround must not drop as RTT grows: {mt} after {prev} (rtt {rtt})"
        );
        prev = mt;
    }
    let base = rows[0].1.mean_turnaround();
    let worst = rows.last().unwrap().1.mean_turnaround();
    // 2 s RTT per probe on multi-task jobs: the tail of the sweep must
    // sit well above the free-frontend baseline, not within noise.
    assert!(
        worst > base + 2.0,
        "sweep should visibly penalise turnaround: {base} -> {worst}"
    );
}

#[test]
fn sweep_model_is_off_only_at_zero() {
    assert!(sweep_model(0.0).is_off());
    for &rtt in &RTT_SWEEP[1..] {
        let m = sweep_model(rtt);
        assert!(!m.is_off());
        assert_eq!(m.probe_rtt_s, rtt);
        assert!(m.dispatch_base_s > 0.0 && m.frontend_service_s > 0.0);
        // The re-probe variant guards every routing: the staleness
        // bound sits below the landing delay (RTT + dispatch = 3x RTT).
        let g = reprobe_model(rtt);
        assert!(g.reprobe_enabled());
        assert!(g.reprobe_after_s < g.probe_rtt_s + g.dispatch_base_s);
    }
    assert!(!reprobe_model(0.0).reprobe_enabled(), "zero RTT: nothing to guard");
}

#[test]
fn latency_aware_dispatch_never_loses_to_least_loaded_on_the_sweep() {
    // The PR acceptance bound: at every swept RTT (uniform across the
    // cluster) the latency-aware dispatcher's mean turnaround is <=
    // least-loaded's. On a homogeneous, uniform-RTT cluster the equal
    // landing delays cancel out of its score, so it must make the very
    // same decisions — the bound holds with equality, and any regression
    // that makes it *worse* than least is a real routing bug.
    for (rtt, rows) in latency_dispatch_comparison(2) {
        let turnaround = |name: &str| {
            rows.iter()
                .find(|(n, _)| *n == name)
                .unwrap_or_else(|| panic!("row '{name}' missing at rtt {rtt}"))
                .1
                .mean_turnaround()
        };
        let (least, latency) = (turnaround("least"), turnaround("latency"));
        assert!(
            latency <= least + 1e-9,
            "rtt {rtt}: latency-aware {latency} must not lose to least {least}"
        );
        // The guarded-routing row rides along: with the staleness bound
        // below every landing delay each routing is re-probed, and the
        // bounded budget must still let every job land and finish.
        for (name, r) in &rows {
            assert_eq!(r.crashed(), 0, "rtt {rtt} {name}: no crashes");
            assert_eq!(r.completed(), 16, "rtt {rtt} {name}: jobs conserved");
        }
        assert!(
            rows.iter().any(|(n, _)| *n == "least+reprobe"),
            "rtt {rtt}: reprobe row present"
        );
    }
}

//! Randomized property tests over the compiler, lazy runtime, engine and
//! schedulers. The offline crate set has no proptest, so this uses the
//! in-tree deterministic PRNG and a small check-many-cases helper — each
//! property runs across hundreds of seeded random cases and reports the
//! first failing seed for replay.

use mgb::compiler::{compile, CompiledProgram};
use mgb::coordinator::{
    run_batch, run_cluster_traced, run_cluster_traced_on_backend, ClusterConfig, JobClass,
    JobSpec, RunConfig, SchedMode,
};
use mgb::gpu::{
    ClusterSpec, Device, GpuSpec, InterferenceProfile, InterferenceResponse, LatencyModel,
    NodeSpec,
};
use mgb::ir::{Expr, OpKind, Program, ProgramBuilder};
use mgb::lazy::{interpret, TraceEvent};
use mgb::sched::{make_policy, DeviceView, TaskReq};
use mgb::workloads::rng::Rng;
use mgb::workloads::{poisson_arrivals, Workload};

/// Run `prop` for `cases` seeds; panic with the seed on first failure.
fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: u64, prop: F) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_default();
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// A random host program: 1-4 task groups, each with 1-4 buffers, 1-3
/// launches, optional loop, optional shared buffer with the previous
/// group, optional branch-guarded D2H (which forces laziness).
fn random_program(rng: &mut Rng) -> Program {
    let n_groups = 1 + rng.below(4);
    let mut pb = ProgramBuilder::new();
    let groups: Vec<(usize, usize, bool, bool, bool)> = (0..n_groups)
        .map(|_| {
            (
                1 + rng.below(4),     // buffers
                1 + rng.below(3),     // launches
                rng.below(3) == 0,    // loop?
                rng.below(4) == 0,    // branch-guarded d2h?
                rng.below(3) == 0,    // share a buffer with previous group?
            )
        })
        .collect();
    let sizes: Vec<i64> = (0..n_groups).map(|_| (1 + rng.below(64)) as i64 * (1 << 20)).collect();
    pb.func("main", 1, |f| {
        let mut prev_buf = None;
        for (g, &(n_bufs, n_launches, looped, branchy, share)) in groups.iter().enumerate() {
            let sz = f.assign(Expr::c(sizes[g]));
            let mut bufs: Vec<_> = (0..n_bufs).map(|_| f.malloc(sz)).collect();
            if share {
                if let Some(p) = prev_buf {
                    bufs.push(p);
                }
            }
            f.h2d(bufs[0], sz);
            let grid = f.c(64 + (sizes[g] % 512));
            let block = f.c(128);
            let work = f.c(1000 + sizes[g] % 9000);
            if looped {
                let trips = f.c(2 + (sizes[g] % 5));
                let args = bufs.clone();
                f.loop_n(trips, |f| {
                    for l in 0..n_launches {
                        f.launch(&format!("k{g}_{l}"), grid, block, &args, work);
                    }
                });
            } else {
                for l in 0..n_launches {
                    f.launch(&format!("k{g}_{l}"), grid, block, &bufs, work);
                }
            }
            if branchy {
                let cond = f.c(1);
                let b0 = bufs[0];
                f.diamond(cond, |f| f.d2h(b0, sz), |_| {});
            } else {
                f.d2h(bufs[0], sz);
            }
            // Free only the buffers this group allocated (a shared one
            // belongs to the earlier group and was already freed there —
            // double frees are invalid IR we don't generate).
            for &b in bufs.iter().take(n_bufs) {
                f.free(b);
            }
            prev_buf = Some(bufs[0]);
        }
    });
    pb.finish()
}

fn compiled(rng: &mut Rng) -> CompiledProgram {
    compile(&random_program(rng))
}

#[test]
fn prop_every_launch_lands_in_exactly_one_task() {
    check(300, |rng| {
        let c = compiled(rng);
        let f = c.program.main();
        for (_, _, op) in f.ops() {
            if matches!(op.kind, OpKind::Launch { .. }) {
                let owners = c.tasks.iter().filter(|t| t.launches.contains(&op.id)).count();
                assert_eq!(owners, 1, "launch {} owned by {owners} tasks", op.id);
            }
        }
    });
}

#[test]
fn prop_merged_tasks_have_disjoint_mem_objs() {
    check(300, |rng| {
        let c = compiled(rng);
        for (i, a) in c.tasks.iter().enumerate() {
            for b in c.tasks.iter().skip(i + 1) {
                for m in &a.mem_objs {
                    assert!(
                        !b.mem_objs.contains(m),
                        "tasks {} and {} share memobj v{m} but were not merged",
                        a.id,
                        b.id
                    );
                }
            }
        }
    });
}

#[test]
fn prop_static_probe_dominates_every_task_op() {
    check(300, |rng| {
        let c = compiled(rng);
        let f = c.program.main();
        for t in &c.tasks {
            let Some(probe) = t.probe_at else { continue };
            for &o in &t.ops {
                let loc = f.loc(o);
                // The probe is at-or-before the first op in the entry
                // block ordering; every op must not precede it in its
                // own block if same block.
                if loc.0 == probe.0 {
                    assert!(probe.1 <= loc.1, "probe after op {o} in same block");
                }
            }
        }
    });
}

#[test]
fn prop_interpreted_traces_are_well_formed() {
    check(300, |rng| {
        let c = compiled(rng);
        let trace = interpret(&c, &[1 << 20]).expect("interprets");
        trace.check_well_formed().unwrap();
        // Every launch in the IR shows up in the trace at least once.
        let ir_launches = c
            .program
            .main()
            .ops()
            .filter(|(_, _, o)| matches!(o.kind, OpKind::Launch { .. }))
            .count();
        let trace_launches = trace
            .events
            .iter()
            .filter(|e| matches!(e, TraceEvent::Launch { .. }))
            .count();
        assert!(trace_launches >= ir_launches, "{trace_launches} < {ir_launches}");
    });
}

#[test]
fn prop_task_begin_precedes_all_its_device_ops() {
    check(200, |rng| {
        let c = compiled(rng);
        let trace = interpret(&c, &[1 << 20]).expect("interprets");
        let mut begun = std::collections::HashSet::new();
        for e in &trace.events {
            match e {
                TraceEvent::TaskBegin { task, .. } => {
                    begun.insert(*task);
                }
                TraceEvent::Malloc { task, .. }
                | TraceEvent::Launch { task, .. }
                | TraceEvent::H2D { task, .. }
                | TraceEvent::D2H { task, .. }
                | TraceEvent::Free { task, .. } => {
                    assert!(begun.contains(task), "op before TaskBegin of {task}");
                }
                _ => {}
            }
        }
    });
}

#[test]
fn prop_probe_resources_cover_interpreted_allocations() {
    // The probe's memory figure must cover every byte the task actually
    // allocates (memory safety hinges on this).
    check(200, |rng| {
        let c = compiled(rng);
        let trace = interpret(&c, &[1 << 20]).expect("interprets");
        let mut reserved: std::collections::HashMap<usize, u64> = Default::default();
        let mut allocated: std::collections::HashMap<usize, u64> = Default::default();
        for e in &trace.events {
            match e {
                TraceEvent::TaskBegin { task, res } => {
                    reserved.insert(*task, res.mem_bytes);
                }
                TraceEvent::Malloc { task, bytes } => {
                    *allocated.entry(*task).or_insert(0) += bytes;
                }
                _ => {}
            }
        }
        for (task, alloc) in allocated {
            let res = reserved.get(&task).copied().unwrap_or(0);
            assert!(res >= alloc, "task {task}: reserved {res} < allocated {alloc}");
        }
    });
}

#[test]
fn prop_random_batches_conserve_jobs_and_memory_safety() {
    check(60, |rng| {
        let n_jobs = 4 + rng.below(12);
        let jobs: Vec<JobSpec> = (0..n_jobs)
            .map(|i| {
                let c = compiled(rng);
                let trace = interpret(&c, &[1 << 20]).expect("interprets");
                JobSpec {
                    name: format!("rand-{i}"),
                    class: JobClass::Small,
                    trace,
                    arrival: 0.0,
                    slo: None,
                }
            })
            .collect();
        let workers = 1 + rng.below(12);
        let policy = ["mgb2", "mgb3", "schedgpu"][rng.below(3)];
        let r = run_batch(
            RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy(policy), workers },
            jobs,
        );
        assert_eq!(r.completed() + r.crashed(), n_jobs);
        assert_eq!(r.crashed(), 0, "{policy} must be memory-safe");
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
    });
}

#[test]
fn prop_placements_always_fit_free_memory() {
    check(300, |rng| {
        let n_dev = 1 + rng.below(4);
        let policy_name = ["mgb2", "mgb3", "schedgpu"][rng.below(3)];
        let mut policy = make_policy(policy_name, n_dev);
        let mut free: Vec<u64> = (0..n_dev).map(|_| ((1 + rng.below(16)) as u64) << 30).collect();
        for i in 0..30 {
            let views: Vec<DeviceView> = free
                .iter()
                .map(|&f| DeviceView { spec: GpuSpec::v100(), free_mem: f })
                .collect();
            let req = TaskReq {
                mem_bytes: (rng.below(18) as u64) << 30,
                tbs: 1 + rng.below(2000) as u64,
                warps_per_tb: 1 + rng.below(8) as u64,
                slo: None,
                iv: InterferenceProfile::ZERO,
            };
            if let Some(d) = policy.place((i, 0), &req, &views) {
                assert!(
                    req.mem_bytes <= free[d],
                    "{policy_name} placed {} bytes on device with {} free",
                    req.mem_bytes,
                    free[d]
                );
                free[d] -= req.mem_bytes;
            }
        }
    });
}

#[test]
fn prop_zero_vector_cluster_streams_are_replay_and_backend_identical() {
    // The interference tentpole's off-path contract at event
    // granularity: with every vector at its all-zero default, a
    // multi-thousand-event open-system cluster run fires byte-identical
    // streams run-to-run and across event-queue backends. The
    // interference plumbing (per-node pressure charging, per-task
    // vector threading, the device's aggregate check) must add no
    // nondeterminism and perturb no zero-pressure code path.
    let cluster_cfg = |dispatch: &'static str| ClusterConfig {
        cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 16,
        dispatch,
        preempt: None,
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    for dispatch in ["least", "mem"] {
        let mut jobs = Workload::by_id("W1").unwrap().jobs(11);
        jobs.extend(Workload::by_id("W2").unwrap().jobs(13));
        poisson_arrivals(&mut jobs, 1.5, 11);
        assert!(
            jobs.iter().all(|j| j.trace.peak_interference().is_zero()),
            "unstamped mixes must carry all-zero vectors"
        );
        let (a, ta) = run_cluster_traced(cluster_cfg(dispatch), jobs.clone());
        let (_, tb) = run_cluster_traced(cluster_cfg(dispatch), jobs.clone());
        let (c, tc) = run_cluster_traced_on_backend(cluster_cfg(dispatch), jobs, "heap");
        assert_eq!(ta, tb, "{dispatch}: zero-vector replay must be byte-identical");
        assert_eq!(ta, tc, "{dispatch}: backends must agree on the zero-vector stream");
        assert!(ta.len() >= 1_000, "{dispatch}: stream too small to mean much: {}", ta.len());
        assert_eq!(a.makespan, c.makespan);
        assert_eq!(a.completed(), c.completed());
    }
}

#[test]
fn prop_interference_slowdown_is_monotone_and_clamped() {
    // Response-level property: for any own-profile, slowdown is >= 1,
    // <= max_slowdown, and monotone non-decreasing as co-resident
    // pressure accumulates component by component.
    check(300, |rng| {
        let resp = InterferenceResponse::default();
        let frac = |rng: &mut Rng| rng.below(101) as f64 / 100.0;
        let own = InterferenceProfile::new(frac(rng), frac(rng), frac(rng));
        let mut others = InterferenceProfile::ZERO;
        let mut prev = resp.slowdown(&own, &others);
        assert_eq!(prev, 1.0, "no co-residents, no slowdown");
        for _ in 0..12 {
            let delta = InterferenceProfile::new(
                frac(rng) * 0.5,
                frac(rng) * 0.5,
                frac(rng) * 0.5,
            );
            others = others.add(&delta);
            let s = resp.slowdown(&own, &others);
            assert!(s >= prev - 1e-12, "monotone: {s} after {prev}");
            assert!((1.0..=resp.max_slowdown).contains(&s), "clamped: {s}");
            prev = s;
        }
    });
}

#[test]
fn prop_device_rates_stay_within_the_interference_envelope() {
    // Device-level property: a kernel's interference-normalised rate
    // (MPS overhead factored out) never exceeds its dedicated rate and
    // never falls below dedicated / max_slowdown, for random profiles
    // and random co-resident counts. Warp totals stay under the
    // device's compute headroom so processor sharing stays out of the
    // picture and the envelope isolates the interference term.
    check(150, |rng| {
        let spec = GpuSpec::v100();
        let frac = |rng: &mut Rng| rng.below(101) as f64 / 100.0;
        let own = InterferenceProfile::new(frac(rng), frac(rng), frac(rng));
        let warps = 1 + rng.below(512) as u64;
        let dedicated = {
            let mut d = Device::new(spec);
            d.advance_to(0.0);
            let h = d.start_kernel_with(0.0, 1.0, warps, own);
            1.0 / d.eta_at(0.0, h).expect("resident")
        };
        let mut d = Device::new(spec);
        d.advance_to(0.0);
        let h = d.start_kernel_with(0.0, 1.0, warps, own);
        let n = 1 + rng.below(6);
        for _ in 0..n {
            let iv = InterferenceProfile::new(frac(rng), frac(rng), frac(rng));
            d.start_kernel_with(0.0, 1.0, 1 + rng.below(512) as u64, iv);
        }
        let rate = 1.0 / d.eta_at(0.0, h).expect("still resident");
        let mps = 1.0 + mgb::gpu::device::MPS_PER_NEIGHBOUR * n as f64;
        let normalised = rate * mps;
        let max_slow = spec.interference.max_slowdown;
        assert!(
            normalised <= dedicated * (1.0 + 1e-9),
            "co-residency sped a kernel up: {normalised} > {dedicated}"
        );
        assert!(
            normalised >= dedicated / max_slow - 1e-9,
            "rate {normalised} fell below dedicated {dedicated} / max_slowdown {max_slow}"
        );
    });
}

#[test]
fn prop_display_parse_roundtrip() {
    // The textual IR form is a faithful serialization: printing any
    // random program and re-parsing it reproduces the same text.
    check(300, |rng| {
        let p = random_program(rng);
        let text = p.to_string();
        let p2 = mgb::ir::parse::parse_program(&text)
            .unwrap_or_else(|e| panic!("reparse failed: {e:#}\n{text}"));
        assert_eq!(text, p2.to_string());
        // And the reparsed program compiles to the same task structure.
        let (a, b) = (compile(&p), compile(&p2));
        assert_eq!(a.tasks.len(), b.tasks.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.lazy, y.lazy);
            assert_eq!(x.launches.len(), y.launches.len());
            assert_eq!(x.mem_objs, y.mem_objs);
        }
    });
}

//! Integration: AOT artifacts (python -m compile.aot) load, compile and
//! execute on the rust PJRT client with correct numerics.
//!
//! Requires `make artifacts` to have been run (skips, loudly, otherwise).

use mgb::runtime::{KernelRegistry, PjrtRuntime};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_client_comes_up() {
    let rt = PjrtRuntime::cpu().expect("cpu client");
    assert_eq!(rt.platform_name(), "cpu");
    assert!(rt.device_count() >= 1);
}

#[test]
fn dwt2d_executes_with_correct_numerics() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = KernelRegistry::new(dir).unwrap();
    let exe = reg.get("dwt2d").unwrap();
    // Constant image: Haar LL subband = 2*c, other subbands = 0.
    let img = vec![3.0f32; 128 * 128];
    let out = exe.run_f32(&[(&img, &[128, 128])]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), 128 * 128);
    // LL occupies rows 0..64, cols 0..64 of the output layout.
    let ll = out[0][0];
    assert!((ll - 6.0).abs() < 1e-5, "LL={ll}");
    let hh = out[0][64 * 128 + 64];
    assert!(hh.abs() < 1e-5, "HH={hh}");
}

#[test]
fn pallas_lowered_srad_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let reg = KernelRegistry::new(dir).unwrap();
    let exe = reg.get("srad").unwrap();
    // Constant image: all gradients zero => diffusion is a no-op.
    let img = vec![1.5f32; 128 * 128];
    let out = exe.run_f32(&[(&img, &[128, 128])]).unwrap();
    for (i, v) in out[0].iter().enumerate() {
        assert!((v - 1.5).abs() < 1e-4, "pixel {i} = {v}");
    }
}

#[test]
fn every_manifest_artifact_compiles() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = std::fs::read_to_string(dir.join("manifest.txt")).unwrap();
    let reg = KernelRegistry::new(dir).unwrap();
    let mut n = 0;
    for line in manifest.lines() {
        let name = line.split(';').next().unwrap();
        reg.get(name).unwrap_or_else(|e| panic!("compiling {name}: {e}"));
        n += 1;
    }
    assert!(n >= 11, "expected >= 11 artifacts, saw {n}");
}

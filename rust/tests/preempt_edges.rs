//! Checkpoint/restart preemption edge cases the unit suite did not
//! cover: a victim that is already mid-checkpoint when a second probe
//! blocks, a preemption budget exhausted mid-cascade, the
//! `--preempt never` == disabled equivalence on a *heterogeneous*
//! P100/V100 cluster (the existing exact-equality test is homogeneous),
//! and the cross-node migration edges — a victim whose home node fills
//! while its checkpoint is in flight must migrate rather than queue
//! behind the contention, `--migrate off` must fire no migration event
//! and replay deterministically, and the re-probe guard must arm over a
//! migrating restore's journey like any routed RPC.

use mgb::coordinator::{run_cluster, run_cluster_traced, ClusterConfig, JobClass, SchedMode};
use mgb::gpu::{ClusterSpec, GpuSpec, LatencyModel, NodeSpec};
use mgb::sched::PreemptConfig;
use mgb::workloads::synthetic_job;

fn v100x1() -> NodeSpec {
    NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() }
}

fn one_node_cfg(preempt: Option<PreemptConfig>) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::single(v100x1()),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 3,
        dispatch: "rr",
        preempt,
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

#[test]
fn victim_already_checkpointing_is_not_selected_twice() {
    // A 120 s hog holds 12 GB; two heavies block in the same instant
    // (t = 5, FIFO order h1 then h2). h1's probe selects the hog and
    // marks it `Checkpointing`; when h2's probe fails a moment later —
    // before the hog's CkptBegin has even fired, so its kernel is
    // still formally in flight and its preemption count still 0 — only
    // the per-node ckpt-in-flight guard and the phase filter stand
    // between it and a double eviction (the budget cannot help: it is
    // only charged at CkptBegin, and is raised to 2 here anyway).
    // Expect exactly one preemption, no double release of the hog's
    // reservations, and everyone completing.
    let jobs = vec![
        synthetic_job("hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
        synthetic_job("h1", JobClass::Large, 12 << 30, 1_500_000, 5.0),
        synthetic_job("h2", JobClass::Large, 12 << 30, 1_500_000, 5.0),
    ];
    // ckpt cost ~2.07 s for a 12 GiB image: bigger than a heavy's 1.5 s
    // ETA, so min-progress never turns on the heavies themselves.
    let cfg =
        PreemptConfig { ckpt_base_s: 1.0, max_preemptions: 2, ..PreemptConfig::default() };
    let r = run_cluster(one_node_cfg(Some(cfg)), jobs);
    assert_eq!(r.completed(), 3, "nobody is lost to the refused eviction");
    assert_eq!(r.preemptions, 1, "one eviction serves both blocked heavies");
    assert_eq!(r.jobs[0].preemptions, 1, "the hog is the only victim");
    assert_eq!(r.jobs[1].preemptions + r.jobs[2].preemptions, 0);
    // Both heavies clear while the hog is parked (it restarts after).
    assert!(r.jobs[1].turnaround() < 20.0, "h1 {}", r.jobs[1].turnaround());
    assert!(r.jobs[2].turnaround() < 20.0, "h2 {}", r.jobs[2].turnaround());
    assert!(r.makespan > 120.0, "the hog still pays its full runtime");
}

#[test]
fn preemption_budget_exhausts_mid_cascade() {
    // Budget 2: the hog is evicted for h1 and again for h2, then h3
    // finds the budget spent and must wait out the hog's remaining
    // ~220 s instead of triggering a third eviction.
    let jobs = vec![
        synthetic_job("hog", JobClass::Small, 12 << 30, 300_000_000, 0.0),
        synthetic_job("h1", JobClass::Large, 12 << 30, 10_000_000, 5.0),
        synthetic_job("h2", JobClass::Large, 12 << 30, 10_000_000, 40.0),
        synthetic_job("h3", JobClass::Large, 12 << 30, 10_000_000, 80.0),
    ];
    let cfg = PreemptConfig { max_preemptions: 2, ..PreemptConfig::default() };
    let r = run_cluster(one_node_cfg(Some(cfg)), jobs);
    assert_eq!(r.completed(), 4);
    assert_eq!(r.preemptions, 2, "third eviction must be refused");
    assert_eq!(r.jobs[0].preemptions, 2, "both evictions hit the hog");
    assert!(r.jobs[1].turnaround() < 30.0, "h1 {}", r.jobs[1].turnaround());
    assert!(r.jobs[2].turnaround() < 30.0, "h2 {}", r.jobs[2].turnaround());
    assert!(
        r.jobs[3].turnaround() > 150.0,
        "h3 must wait out the protected hog: {}",
        r.jobs[3].turnaround()
    );
    assert!(r.makespan > 300.0, "the hog's 300 s of work still happens");
}

#[test]
fn preempt_never_matches_disabled_on_heterogeneous_cluster() {
    // `--preempt never` must leave every observable bit identical to
    // preemption-off on a mixed P100/V100 cluster — the heterogeneous
    // dispatch normalisation and the preemption plumbing must not
    // interact. (The pre-existing equivalence test only covered a
    // homogeneous 1xV100 cluster.)
    let het_cfg = |preempt: Option<PreemptConfig>| ClusterConfig {
        cluster: ClusterSpec::of(vec![NodeSpec::p100x2(), NodeSpec::v100x4()]),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 6,
        dispatch: "least",
        preempt,
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let mut jobs: Vec<_> = (0..10)
        .map(|i| {
            synthetic_job(
                &format!("j{i}"),
                if i % 3 == 0 { JobClass::Large } else { JobClass::Small },
                (6 + (i % 3) * 4) as u64 * (1 << 30), // 6/10/14 GB: contended
                3_000_000,
                0.0,
            )
        })
        .collect();
    for (i, j) in jobs.iter_mut().enumerate() {
        j.arrival = i as f64 * 0.5;
    }
    let off = run_cluster(het_cfg(None), jobs.clone());
    let never =
        run_cluster(het_cfg(Some(PreemptConfig { policy: "never", ..Default::default() })), jobs);
    assert_eq!(off.preemptions, 0);
    assert_eq!(never.preemptions, 0);
    assert_eq!(off.wasted_work_s, 0.0);
    assert_eq!(never.wasted_work_s, 0.0);
    assert_eq!(off.makespan, never.makespan, "never must not perturb timing");
    for (x, y) in off.jobs.iter().zip(&never.jobs) {
        assert_eq!(x.started, y.started, "{}", x.name);
        assert_eq!(x.ended, y.ended, "{}", x.name);
        assert_eq!(x.node, y.node, "{}", x.name);
        assert_eq!(x.crashed, y.crashed, "{}", x.name);
    }
    // The scenario must actually exercise both node types.
    let per_node = off.jobs_per_node();
    assert!(per_node.iter().all(|&n| n > 0), "both nodes serve jobs: {per_node:?}");
}

// ---- cross-node checkpoint migration ---------------------------------

/// Two 1xV100 nodes under round-robin dispatch (cursor order makes the
/// dance hand-computable): hog -> node 0, filler -> node 1, and the
/// heavy late arrival -> node 0, where it blocks and evicts the hog.
fn migration_cfg(migrate: &'static str) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(v100x1(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "rr",
        preempt: Some(PreemptConfig { migrate, ..PreemptConfig::default() }),
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

/// hog holds 12 GB for 120 s on node 0; the 12 GB heavy that evicts it
/// at t = 5 then occupies the node for its own 100 s — so by the time
/// the hog's checkpoint image is written, its home node has *filled*
/// and a same-node restore strands it behind the heavy's residency.
fn migration_jobs() -> Vec<mgb::coordinator::JobSpec> {
    vec![
        synthetic_job("hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
        synthetic_job("filler", JobClass::Small, 1 << 30, 1_000_000, 0.0),
        synthetic_job("heavy", JobClass::Large, 12 << 30, 100_000_000, 5.0),
    ]
}

#[test]
fn victim_migrates_when_its_home_node_fills_mid_checkpoint() {
    // Same-node-only restore: the hog re-queues on node 0 behind the
    // very heavy that evicted it and waits out its ~103 s residency.
    let off = run_cluster(migration_cfg("off"), migration_jobs());
    assert_eq!(off.completed(), 3, "no deadlock either way");
    assert_eq!((off.migrations, off.migrate_bytes), (0, 0));
    assert_eq!(off.preemptions, 1);
    assert_eq!(off.jobs[0].node, 0, "restore is pinned to the home node");
    assert!(off.jobs[0].ended > 200.0, "hog strands behind the heavy: {}", off.jobs[0].ended);
    // Cluster-wide restore: the saved reservation set re-enters the
    // frontend, the rr cursor routes it to node 1 (idle since the
    // filler finished), and the hog restores as soon as its 12 GiB
    // image lands there — ~90 s sooner.
    let on = run_cluster(migration_cfg("cluster"), migration_jobs());
    assert_eq!(on.completed(), 3, "migration must not lose anybody");
    assert_eq!(on.preemptions, 1);
    assert_eq!(on.migrations, 1, "exactly one cross-node restore");
    assert_eq!(on.migrate_bytes, 12 << 30, "the full image crossed the link");
    assert_eq!(on.jobs[0].node, 1, "the hog finishes on the other node");
    assert!(on.jobs[0].ended < 160.0, "migrated restore escapes the wait: {}", on.jobs[0].ended);
    assert!(on.jobs[0].ended > 130.0, "but still pays transfer + restore + full kernel");
    // The eviction beneficiary is untouched by where the victim went.
    assert_eq!(on.jobs[2].started, off.jobs[2].started, "heavy unaffected by migration");
    assert_eq!(on.jobs[2].ended, off.jobs[2].ended);
}

#[test]
fn migrate_off_fires_no_migration_events_and_replays_bit_identically() {
    // `--migrate off` IS the default, and must take the exact PR-2/PR-4
    // restore path: a preempting run fires the checkpoint protocol but
    // never a MigrateArrive, and the full event stream replays
    // byte-for-byte (the committed golden fixtures lock the
    // preemption-disabled paths across PRs; this locks the enabled,
    // unmigrated ones within one).
    assert_eq!(PreemptConfig::default().migrate, "off");
    let (a, ta) = run_cluster_traced(migration_cfg("off"), migration_jobs());
    let (b, tb) = run_cluster_traced(migration_cfg("off"), migration_jobs());
    assert_eq!(ta, tb, "migrate-off preemption replays bit-identically");
    assert_eq!(a.makespan, b.makespan);
    assert!(ta.iter().any(|l| l.contains("CkptBegin")), "scenario must preempt");
    assert!(ta.iter().any(|l| l.contains("CkptDone")));
    assert!(ta.iter().any(|l| l.contains("Restart")));
    assert!(
        !ta.iter().any(|l| l.contains("MigrateArrive")),
        "migrate off must never push a migration event"
    );
    // And the cluster mode is what introduces them — nothing else.
    let (_, tc) = run_cluster_traced(migration_cfg("cluster"), migration_jobs());
    assert_eq!(
        tc.iter().filter(|l| l.contains("MigrateArrive")).count(),
        1,
        "cluster restore lands exactly once"
    );
}

#[test]
fn migrating_restore_never_routes_to_a_node_that_cannot_hold_it() {
    // Memory-oblivious dispatch (rr) would send the evicted hog's
    // restore to the 8 GB node by cursor order — where its 12 GB saved
    // reservation can never re-place and the drain fallback would
    // misreport a crash. The frontend must override the infeasible
    // route and land the restore back home, where it simply waits out
    // the heavy like a same-node restore.
    let small = NodeSpec {
        gpus: vec![GpuSpec { mem_bytes: 8 << 30, ..GpuSpec::v100() }],
        cpu_cores: 8,
        name: "1xSmall".into(),
    };
    let cfg = ClusterConfig {
        cluster: ClusterSpec::of(vec![v100x1(), small]),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "rr",
        preempt: Some(PreemptConfig { migrate: "cluster", ..PreemptConfig::default() }),
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let jobs = vec![
        synthetic_job("hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
        synthetic_job("filler", JobClass::Small, 1 << 30, 1_000_000, 0.0),
        synthetic_job("heavy", JobClass::Large, 12 << 30, 100_000_000, 5.0),
    ];
    let r = run_cluster(cfg, jobs);
    assert_eq!(r.crashed(), 0, "the restore must not die to an infeasible route");
    assert_eq!(r.completed(), 3);
    assert_eq!(r.preemptions, 1);
    assert_eq!(r.migrations, 0, "falling back home is not a migration");
    assert_eq!(r.jobs[0].node, 0, "the hog lands back on the only node that fits it");
    assert!(r.jobs[0].ended > 200.0, "home restore waits out the heavy: {}", r.jobs[0].ended);
}

#[test]
fn reprobe_guard_arms_over_a_migrating_restore_journey() {
    // Migration + `--reprobe-after`: the restore job is an RPC like any
    // arrival, so a landing delay (RTT 0.1 + dispatch 2.0) above the
    // staleness bound (1.8) puts a ReProbe guard on its routing too.
    // Scenario (least-loaded, so the guard arms): hog (12 GB, 120 s
    // est) -> node 0; busy (1 GB, 150 s est) -> node 1; the heavy
    // (12 GB, 200 s) routes to node 0 — the *lighter* queue — blocks,
    // and evicts the hog. The migration decision then sees node 0
    // carrying the heavy's 200 s vs busy's 150 s and routes the restore
    // cross-node; its re-probe fires at the bound, re-snapshots,
    // confirms (loads did not flip), and the landing commits at the
    // original instant plus the image transfer. Each of the hog's two
    // guarded journeys — arrival and restore — spends one re-probe.
    let lat = LatencyModel {
        probe_rtt_s: 0.1,
        dispatch_base_s: 2.0,
        reprobe_after_s: 1.8,
        reprobe_budget: 2,
        ..LatencyModel::default()
    };
    let cfg = || ClusterConfig {
        cluster: ClusterSpec::homogeneous(v100x1(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "least",
        preempt: Some(PreemptConfig { migrate: "cluster", ..PreemptConfig::default() }),
        latency: lat.clone(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let jobs = || {
        vec![
            synthetic_job("hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
            synthetic_job("busy", JobClass::Small, 1 << 30, 150_000_000, 0.0),
            synthetic_job("heavy", JobClass::Large, 12 << 30, 200_000_000, 5.0),
        ]
    };
    let (a, ta) = run_cluster_traced(cfg(), jobs());
    let (b, tb) = run_cluster_traced(cfg(), jobs());
    assert_eq!(ta, tb, "guarded migration replays bit-for-bit");
    assert_eq!(a.completed(), 3);
    assert_eq!(a.preemptions, 1);
    assert_eq!(a.migrations, 1, "the restore landed cross-node");
    assert_eq!(a.migrate_bytes, 12 << 30);
    assert_eq!(a.jobs[0].node, 1, "hog finishes on the busy-but-lighter node");
    let hog_reprobes = ta.iter().filter(|l| l.contains("ReProbe { job: 0 }")).count();
    assert_eq!(
        hog_reprobes, 2,
        "one guarded arrival + one guarded restore journey: {hog_reprobes}"
    );
    assert_eq!(ta.iter().filter(|l| l.contains("MigrateArrive { job: 0 }")).count(), 1);
    // The confirmed landing pays RTT + dispatch + the 12 GiB transfer
    // after the checkpoint — the hog cannot be running again before it.
    assert!(a.jobs[0].ended > 130.0 && a.jobs[0].ended < 160.0, "{}", a.jobs[0].ended);
}

#[test]
fn reprobe_redirects_a_migrating_restore_whose_target_stales() {
    // The other half of the satellite: a re-probe may *redirect* a
    // restore. The lever is a completion inside the staleness window —
    // under least-loaded, arrivals are biased away from the restore's
    // chosen node by its own re-charge, so only an un-charge can flip
    // the ranking. Timeline (rtt 0.1, dispatch 2.0, bound 1.8):
    //
    //   t=0    hog (12 GB, 120 s est) -> n0; busy (1 GB, 150 s) -> n1
    //   t=1    shortie (1 GB, 6 s) -> n0 (126 total), done ~9.38
    //   t=5    heavy (12 GB, 147 s) -> n0 (lighter: 126 < 150), blocks,
    //          evicts the hog; CkptDone ~8.22
    //   t~8.22 restore decision: n0 = 147+6 = 153 > n1 = 150 -> route
    //          n1 (cross-node: the 12 GiB transfer arms the guard)
    //   t~9.38 shortie finishes: n0 drops to 147
    //   t~10.0 ReProbe: n0 = 147 < n1 = 150 -> REDIRECT home; the
    //          image transfer is aborted (xfer drops to zero), the
    //          redirected journey is guarded once more and confirms
    //   t~12.1 MigrateArrive on n0 = home: no migration is counted and
    //          no bytes crossed; the hog then waits out the heavy.
    let lat = LatencyModel {
        probe_rtt_s: 0.1,
        dispatch_base_s: 2.0,
        reprobe_after_s: 1.8,
        reprobe_budget: 3,
        ..LatencyModel::default()
    };
    let cfg = || ClusterConfig {
        cluster: ClusterSpec::homogeneous(v100x1(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "least",
        preempt: Some(PreemptConfig { migrate: "cluster", ..PreemptConfig::default() }),
        latency: lat.clone(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let jobs = || {
        vec![
            synthetic_job("hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
            synthetic_job("busy", JobClass::Small, 1 << 30, 150_000_000, 0.0),
            synthetic_job("shortie", JobClass::Small, 1 << 30, 6_000_000, 1.0),
            synthetic_job("heavy", JobClass::Large, 12 << 30, 147_000_000, 5.0),
        ]
    };
    let (a, ta) = run_cluster_traced(cfg(), jobs());
    let (b, tb) = run_cluster_traced(cfg(), jobs());
    assert_eq!(ta, tb, "redirected migration replays bit-for-bit");
    assert_eq!(a.completed(), 4);
    assert_eq!(a.preemptions, 1);
    assert_eq!(a.jobs[0].node, 0, "the redirect sends the restore back home");
    assert_eq!(a.migrations, 0, "a home landing is not a migration");
    assert_eq!(a.migrate_bytes, 0, "the aborted transfer shipped nothing");
    // Three guarded decisions for the hog: arrival, the cross-node
    // restore (redirected), and the redirected journey (confirmed).
    let hog_reprobes = ta.iter().filter(|l| l.contains("ReProbe { job: 0 }")).count();
    assert_eq!(hog_reprobes, 3, "arrival + redirected restore + confirm: {hog_reprobes}");
    assert_eq!(ta.iter().filter(|l| l.contains("MigrateArrive { job: 0 }")).count(), 1);
    // Landing home (~12.1 s), the hog re-places only after the heavy's
    // 147 s residency — it pays for the dispatcher's choice, not the
    // transfer it never made.
    assert!(a.jobs[0].ended > 250.0 && a.jobs[0].ended < 300.0, "{}", a.jobs[0].ended);
}

//! Checkpoint/restart preemption edge cases the unit suite did not
//! cover: a victim that is already mid-checkpoint when a second probe
//! blocks, a preemption budget exhausted mid-cascade, and the
//! `--preempt never` == disabled equivalence on a *heterogeneous*
//! P100/V100 cluster (the existing exact-equality test is homogeneous).

use mgb::coordinator::{run_cluster, ClusterConfig, JobClass, SchedMode};
use mgb::gpu::{ClusterSpec, GpuSpec, LatencyModel, NodeSpec};
use mgb::sched::PreemptConfig;
use mgb::workloads::synthetic_job;

fn v100x1() -> NodeSpec {
    NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() }
}

fn one_node_cfg(preempt: Option<PreemptConfig>) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::single(v100x1()),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 3,
        dispatch: "rr",
        preempt,
        latency: LatencyModel::off(),
    }
}

#[test]
fn victim_already_checkpointing_is_not_selected_twice() {
    // A 120 s hog holds 12 GB; two heavies block in the same instant
    // (t = 5, FIFO order h1 then h2). h1's probe selects the hog and
    // marks it `Checkpointing`; when h2's probe fails a moment later —
    // before the hog's CkptBegin has even fired, so its kernel is
    // still formally in flight and its preemption count still 0 — only
    // the per-node ckpt-in-flight guard and the phase filter stand
    // between it and a double eviction (the budget cannot help: it is
    // only charged at CkptBegin, and is raised to 2 here anyway).
    // Expect exactly one preemption, no double release of the hog's
    // reservations, and everyone completing.
    let jobs = vec![
        synthetic_job("hog", JobClass::Small, 12 << 30, 120_000_000, 0.0),
        synthetic_job("h1", JobClass::Large, 12 << 30, 1_500_000, 5.0),
        synthetic_job("h2", JobClass::Large, 12 << 30, 1_500_000, 5.0),
    ];
    // ckpt cost ~2.07 s for a 12 GiB image: bigger than a heavy's 1.5 s
    // ETA, so min-progress never turns on the heavies themselves.
    let cfg =
        PreemptConfig { ckpt_base_s: 1.0, max_preemptions: 2, ..PreemptConfig::default() };
    let r = run_cluster(one_node_cfg(Some(cfg)), jobs);
    assert_eq!(r.completed(), 3, "nobody is lost to the refused eviction");
    assert_eq!(r.preemptions, 1, "one eviction serves both blocked heavies");
    assert_eq!(r.jobs[0].preemptions, 1, "the hog is the only victim");
    assert_eq!(r.jobs[1].preemptions + r.jobs[2].preemptions, 0);
    // Both heavies clear while the hog is parked (it restarts after).
    assert!(r.jobs[1].turnaround() < 20.0, "h1 {}", r.jobs[1].turnaround());
    assert!(r.jobs[2].turnaround() < 20.0, "h2 {}", r.jobs[2].turnaround());
    assert!(r.makespan > 120.0, "the hog still pays its full runtime");
}

#[test]
fn preemption_budget_exhausts_mid_cascade() {
    // Budget 2: the hog is evicted for h1 and again for h2, then h3
    // finds the budget spent and must wait out the hog's remaining
    // ~220 s instead of triggering a third eviction.
    let jobs = vec![
        synthetic_job("hog", JobClass::Small, 12 << 30, 300_000_000, 0.0),
        synthetic_job("h1", JobClass::Large, 12 << 30, 10_000_000, 5.0),
        synthetic_job("h2", JobClass::Large, 12 << 30, 10_000_000, 40.0),
        synthetic_job("h3", JobClass::Large, 12 << 30, 10_000_000, 80.0),
    ];
    let cfg = PreemptConfig { max_preemptions: 2, ..PreemptConfig::default() };
    let r = run_cluster(one_node_cfg(Some(cfg)), jobs);
    assert_eq!(r.completed(), 4);
    assert_eq!(r.preemptions, 2, "third eviction must be refused");
    assert_eq!(r.jobs[0].preemptions, 2, "both evictions hit the hog");
    assert!(r.jobs[1].turnaround() < 30.0, "h1 {}", r.jobs[1].turnaround());
    assert!(r.jobs[2].turnaround() < 30.0, "h2 {}", r.jobs[2].turnaround());
    assert!(
        r.jobs[3].turnaround() > 150.0,
        "h3 must wait out the protected hog: {}",
        r.jobs[3].turnaround()
    );
    assert!(r.makespan > 300.0, "the hog's 300 s of work still happens");
}

#[test]
fn preempt_never_matches_disabled_on_heterogeneous_cluster() {
    // `--preempt never` must leave every observable bit identical to
    // preemption-off on a mixed P100/V100 cluster — the heterogeneous
    // dispatch normalisation and the preemption plumbing must not
    // interact. (The pre-existing equivalence test only covered a
    // homogeneous 1xV100 cluster.)
    let het_cfg = |preempt: Option<PreemptConfig>| ClusterConfig {
        cluster: ClusterSpec::of(vec![NodeSpec::p100x2(), NodeSpec::v100x4()]),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 6,
        dispatch: "least",
        preempt,
        latency: LatencyModel::off(),
    };
    let mut jobs: Vec<_> = (0..10)
        .map(|i| {
            synthetic_job(
                &format!("j{i}"),
                if i % 3 == 0 { JobClass::Large } else { JobClass::Small },
                (6 + (i % 3) * 4) as u64 * (1 << 30), // 6/10/14 GB: contended
                3_000_000,
                0.0,
            )
        })
        .collect();
    for (i, j) in jobs.iter_mut().enumerate() {
        j.arrival = i as f64 * 0.5;
    }
    let off = run_cluster(het_cfg(None), jobs.clone());
    let never =
        run_cluster(het_cfg(Some(PreemptConfig { policy: "never", ..Default::default() })), jobs);
    assert_eq!(off.preemptions, 0);
    assert_eq!(never.preemptions, 0);
    assert_eq!(off.wasted_work_s, 0.0);
    assert_eq!(never.wasted_work_s, 0.0);
    assert_eq!(off.makespan, never.makespan, "never must not perturb timing");
    for (x, y) in off.jobs.iter().zip(&never.jobs) {
        assert_eq!(x.started, y.started, "{}", x.name);
        assert_eq!(x.ended, y.ended, "{}", x.name);
        assert_eq!(x.node, y.node, "{}", x.name);
        assert_eq!(x.crashed, y.crashed, "{}", x.name);
    }
    // The scenario must actually exercise both node types.
    let per_node = off.jobs_per_node();
    assert!(per_node.iter().all(|&n| n > 0), "both nodes serve jobs: {per_node:?}");
}

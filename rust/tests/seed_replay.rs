//! Seed-replay property harness: for a window of seeds, every
//! configuration must replay bit-identically (the determinism PR 1 and
//! PR 2 staked their acceptance on, generalised from two ad-hoc tests
//! to a swept property), and the Poisson arrival generator must be
//! monotone and rate-correct. CI shifts the seed window via
//! `MGB_SEED_OFFSET` so two suite runs cover different seeds.

use mgb::coordinator::{run_cluster, ClusterConfig, JobClass, RunResult, SchedMode};
use mgb::gpu::{ClusterSpec, GpuSpec, LatencyModel, NodeSpec};
use mgb::sched::PreemptConfig;
use mgb::workloads::{poisson_arrivals, synthetic_job, Workload};

fn seed_offset() -> u64 {
    std::env::var("MGB_SEED_OFFSET")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0)
}

/// Bitwise equality of everything a replay could legitimately observe.
fn assert_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    assert_eq!(a.makespan, b.makespan, "{ctx}: makespan");
    assert_eq!(a.preemptions, b.preemptions, "{ctx}: preemptions");
    assert_eq!(a.wasted_work_s, b.wasted_work_s, "{ctx}: wasted work");
    assert_eq!(a.ckpt_overhead_s, b.ckpt_overhead_s, "{ctx}: ckpt overhead");
    assert_eq!(a.jobs.len(), b.jobs.len(), "{ctx}: job count");
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.started, y.started, "{ctx}: {} started", x.name);
        assert_eq!(x.ended, y.ended, "{ctx}: {} ended", x.name);
        assert_eq!(x.node, y.node, "{ctx}: {} node", x.name);
        assert_eq!(x.crashed, y.crashed, "{ctx}: {} crashed", x.name);
        assert_eq!(x.preemptions, y.preemptions, "{ctx}: {} preemptions", x.name);
        assert_eq!(x.wasted_s, y.wasted_s, "{ctx}: {} wasted", x.name);
    }
}

#[test]
fn seed_replay_open_system_cluster_is_bit_identical() {
    let base = seed_offset();
    for seed in base..base + 6 {
        let mut jobs = Workload::by_id("W5").unwrap().jobs(seed);
        poisson_arrivals(&mut jobs, 0.4, seed);
        let cfg = ClusterConfig {
            cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), 2),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: 8,
            dispatch: "least",
            preempt: None,
            latency: LatencyModel::off(),
            admit: None,
            frontend_q: "fifo",
            compile_traces: false,
        };
        let a = run_cluster(cfg.clone(), jobs.clone());
        let b = run_cluster(cfg, jobs);
        assert_eq!(a.completed() + a.crashed(), 32, "seed {seed}: jobs conserved");
        assert_identical(&a, &b, &format!("seed {seed}"));
    }
}

#[test]
fn seed_replay_with_latency_and_preemption_is_bit_identical() {
    // The full stack at once: nonzero latency model + checkpoint/
    // restart preemption on a contended two-node cluster.
    let base = seed_offset();
    for seed in base..base + 4 {
        let node =
            NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() };
        let mut jobs = Vec::new();
        for i in 0..4 {
            jobs.push(synthetic_job(
                &format!("hog{i}"),
                JobClass::Small,
                12 << 30,
                60_000_000,
                0.0,
            ));
        }
        for i in 0..6 {
            // Arrival placeholder: the Poisson stamp below is the real
            // (seed-jittered) arrival process for the heavies.
            jobs.push(synthetic_job(
                &format!("heavy{i}"),
                JobClass::Large,
                12 << 30,
                5_000_000,
                0.0,
            ));
        }
        // Hogs at t=0, heavies as Poisson(0.5/s) traffic from t~0 on:
        // each window seed is a new contention pattern.
        poisson_arrivals(&mut jobs[4..], 0.5, seed);
        let cfg = ClusterConfig {
            cluster: ClusterSpec::homogeneous(node, 2),
            mode: SchedMode::Policy("mgb3"),
            workers_per_node: 4,
            dispatch: "least",
            preempt: Some(PreemptConfig::default()),
            latency: LatencyModel {
                probe_rtt_s: 0.02,
                dispatch_base_s: 0.1,
                frontend_service_s: 0.002,
                ..LatencyModel::default()
            },
            admit: None,
            frontend_q: "fifo",
            compile_traces: false,
        };
        let a = run_cluster(cfg.clone(), jobs.clone());
        let b = run_cluster(cfg, jobs);
        assert_eq!(a.completed(), 10, "seed {seed}: everyone finishes");
        assert_identical(&a, &b, &format!("seed {seed} (latency+preempt)"));
    }
}

#[test]
fn poisson_arrivals_are_strictly_monotone_for_every_seed() {
    let base = seed_offset();
    for seed in base..base + 10 {
        let mut jobs: Vec<_> = (0..200)
            .map(|i| synthetic_job(&format!("j{i}"), JobClass::Small, 1 << 20, 1000, 0.0))
            .collect();
        poisson_arrivals(&mut jobs, 1.5, seed);
        let mut prev = 0.0;
        for j in &jobs {
            assert!(
                j.arrival > prev && j.arrival.is_finite(),
                "seed {seed}: arrivals must strictly increase ({} after {prev})",
                j.arrival
            );
            prev = j.arrival;
        }
    }
}

#[test]
fn poisson_arrivals_match_the_requested_rate() {
    // Sample mean of n exponential inter-arrivals has relative std
    // 1/sqrt(n) ~ 1.6% at n = 4000; a 5% band across seeds is a real
    // rate-correctness check, not a tautology.
    let base = seed_offset();
    for seed in base..base + 4 {
        for rate in [0.5f64, 2.0] {
            let mut jobs: Vec<_> = (0..4000)
                .map(|i| synthetic_job(&format!("j{i}"), JobClass::Small, 1 << 20, 1000, 0.0))
                .collect();
            poisson_arrivals(&mut jobs, rate, seed);
            let span = jobs.last().unwrap().arrival;
            let mean_gap = span / jobs.len() as f64;
            let want = 1.0 / rate;
            assert!(
                (mean_gap - want).abs() < 0.05 * want,
                "seed {seed} rate {rate}: mean inter-arrival {mean_gap} vs {want}"
            );
        }
    }
}

//! Corpus-locked lint expectations.
//!
//! Every `.gir` under `tests/lint_corpus/` declares its expected
//! outcome in its first line:
//!
//! * `// expect: clean` — the program must lint with no diagnostics;
//! * `// expect: code[,code...]` — linting must yield exactly that set
//!   of diagnostic codes (and at least one error);
//! * `// expect-parse-error: <substring>` — the program must be
//!   rejected at parse/validate time with an error naming the symbol.
//!
//! The corpus is the contract the verifier is held to across PRs: a
//! seeded violation that stops being reported, a clean program that
//! starts tripping a false positive, or a silently shrinking corpus
//! all fail here.

use mgb::compiler::{compile, verify_compiled};
use mgb::ir::parse::parse_program;
use std::fs;
use std::path::PathBuf;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/lint_corpus")
}

#[test]
fn every_corpus_program_yields_exactly_its_expected_diagnostics() {
    let mut entries: Vec<PathBuf> = fs::read_dir(corpus_dir())
        .expect("tests/lint_corpus exists")
        .map(|e| e.expect("dir entry").path())
        .filter(|p| p.extension().and_then(|e| e.to_str()) == Some("gir"))
        .collect();
    entries.sort();
    assert!(entries.len() >= 11, "corpus must not silently shrink: {} files", entries.len());
    for path in entries {
        let name = path.file_name().unwrap().to_string_lossy().to_string();
        let text = fs::read_to_string(&path).unwrap();
        let header = text.lines().next().unwrap_or("").trim().to_string();
        if let Some(want) = header.strip_prefix("// expect-parse-error:") {
            let want = want.trim();
            let err = parse_program(&text)
                .expect_err(&format!("{name}: must be rejected at parse time"))
                .to_string();
            assert!(err.contains(want), "{name}: parse error should name '{want}', got: {err}");
            continue;
        }
        let want = header
            .strip_prefix("// expect:")
            .unwrap_or_else(|| panic!("{name}: first line must be `// expect: ...`"))
            .trim();
        let program =
            parse_program(&text).unwrap_or_else(|e| panic!("{name}: parse failed: {e:#}"));
        let rep = verify_compiled(&compile(&program));
        if want == "clean" {
            assert!(rep.is_clean(), "{name}: expected clean, got:\n{rep}");
        } else {
            let mut expected: Vec<&str> = want.split(',').map(str::trim).collect();
            expected.sort_unstable();
            expected.dedup();
            assert_eq!(
                rep.codes(),
                expected,
                "{name}: diagnostic codes mismatch; full report:\n{rep}"
            );
            assert!(rep.n_errors() > 0, "{name}: seeded violations must be errors:\n{rep}");
        }
    }
}

#[test]
fn every_builtin_workload_lints_clean() {
    // The acceptance bar the `mgb lint --builtin` CI step re-checks
    // from the binary: no false positives on any shipped program.
    for c in mgb::workloads::COMBOS.iter() {
        let rep = verify_compiled(&compile(&c.program()));
        assert!(rep.is_clean(), "rodinia/{} must lint clean:\n{rep}", c.name);
    }
    for t in mgb::workloads::NN_TASKS.iter() {
        let rep = verify_compiled(&compile(&t.program()));
        assert!(rep.is_clean(), "darknet/{} must lint clean:\n{rep}", t.profile().name);
    }
}

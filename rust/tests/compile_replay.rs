//! Compiled trace replay equivalence suite (the `--compile-traces`
//! contract): macro-stepping is a pure *performance* transformation.
//! A compile-on run must be observationally indistinguishable from the
//! same run compiled off — identical `RunResult` metrics (the
//! event-pressure counters `events_fired` / `peak_events` excepted,
//! since collapsing timer events is the whole point) and an identical
//! observable event stream — across random seeds and every engine
//! feature that interacts with macro entry or decompilation:
//! preemption, the latency model, admission control, interference.
//!
//! The observable subset is `EvKind::is_observable`: everything except
//! the engine's own timers (`Wake`, `DevCompletion`, `MacroSegment`).
//! Streams are compared with the queue-global `seq` column stripped —
//! macro-stepping changes how many timer events are ever pushed, so
//! sequence numbers differ between modes even where the observable
//! events are identical in kind, payload, time, and relative order.

use mgb::coordinator::{
    run_cluster, run_cluster_sanitized, run_cluster_traced, AdmissionConfig, ClusterConfig,
    JobClass, JobSpec, RunResult, SchedMode,
};
use mgb::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use mgb::sched::PreemptConfig;
use mgb::workloads::{assign_interference, poisson_arrivals, synthetic_job, Workload};
use std::fs;
use std::path::PathBuf;

/// Event kinds of the observable stream (see `EvKind::is_observable`;
/// the engine cannot export the list directly because `EvKind` is
/// crate-private, so the golden-style tests match serialised names).
const OBSERVABLE: [&str; 11] = [
    "Arrive",
    "CkptBegin",
    "CkptDone",
    "Restart",
    "ProbeSent",
    "ProbeAck",
    "DispatchArrive",
    "ReProbe",
    "MigrateArrive",
    "AdmitReject",
    "FrontendServe",
];

/// Project a recorded full stream ("t=.. seq=.. Kind { .. }") onto the
/// observable subset with the `seq` column stripped.
fn observable(lines: &[String]) -> Vec<String> {
    lines
        .iter()
        .filter_map(|l| {
            let mut it = l.splitn(3, ' ');
            let t = it.next()?;
            let _seq = it.next()?;
            let rest = it.next()?;
            let kind = rest.split([' ', '{']).next().unwrap_or("");
            if OBSERVABLE.contains(&kind) {
                Some(format!("{t} {rest}"))
            } else {
                None
            }
        })
        .collect()
}

fn cfg(preempt: bool, latency: bool, admit: bool, compile: bool) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), 4),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 16,
        dispatch: "least",
        preempt: preempt.then(PreemptConfig::default),
        latency: if latency { LatencyModel::lan() } else { LatencyModel::off() },
        // A tight token bucket so admission actually rejects under the
        // open-system arrival rate (an idle controller would leave the
        // AdmitReject path untested).
        admit: admit.then(|| AdmissionConfig {
            policy: "token",
            rate_per_s: 0.2,
            burst: 2.0,
            ..Default::default()
        }),
        frontend_q: "fifo",
        compile_traces: compile,
    }
}

/// W1 open-system stream: real multi-kernel Rodinia traces with
/// Poisson arrivals, optionally stamped with per-benchmark
/// interference vectors.
fn stream(seed: u64, interference: bool) -> Vec<JobSpec> {
    let mut jobs = Workload::by_id("W1").unwrap().jobs(seed);
    poisson_arrivals(&mut jobs, 0.5, seed);
    if interference {
        assign_interference(&mut jobs);
    }
    jobs
}

/// Everything in `RunResult` that the compiled-replay contract holds
/// invariant (i.e. all of it except the event-pressure counters).
fn assert_results_equal(label: &str, off: &RunResult, on: &RunResult) {
    assert_eq!(off.makespan, on.makespan, "{label}: makespan");
    assert_eq!(off.preemptions, on.preemptions, "{label}: preemptions");
    assert_eq!(off.wasted_work_s, on.wasted_work_s, "{label}: wasted work");
    assert_eq!(off.ckpt_overhead_s, on.ckpt_overhead_s, "{label}: ckpt overhead");
    assert_eq!(off.migrations, on.migrations, "{label}: migrations");
    assert_eq!(off.migrate_bytes, on.migrate_bytes, "{label}: migrate bytes");
    assert_eq!(off.rejected, on.rejected, "{label}: rejected");
    assert_eq!(off.degraded, on.degraded, "{label}: degraded");
    assert_eq!(off.observable_events, on.observable_events, "{label}: observable events");
    assert_eq!(off.jobs.len(), on.jobs.len(), "{label}: job count");
    for (x, y) in off.jobs.iter().zip(&on.jobs) {
        assert_eq!(x.name, y.name, "{label}: job order");
        assert_eq!(x.started, y.started, "{label}/{}: started", x.name);
        assert_eq!(x.ended, y.ended, "{label}/{}: ended", x.name);
        assert_eq!(x.node, y.node, "{label}/{}: node", x.name);
        assert_eq!(x.crashed, y.crashed, "{label}/{}: crashed", x.name);
        assert_eq!(x.rejected, y.rejected, "{label}/{}: rejected", x.name);
        assert_eq!(x.n_kernels, y.n_kernels, "{label}/{}: n_kernels", x.name);
        assert_eq!(x.preemptions, y.preemptions, "{label}/{}: preemptions", x.name);
        assert_eq!(x.wasted_s, y.wasted_s, "{label}/{}: wasted_s", x.name);
        assert_eq!(
            x.kernel_dedicated_s, y.kernel_dedicated_s,
            "{label}/{}: kernel_dedicated_s",
            x.name
        );
        assert_eq!(x.kernel_actual_s, y.kernel_actual_s, "{label}/{}: kernel_actual_s", x.name);
    }
}

/// First index where two observable streams disagree, for a readable
/// panic instead of a giant Vec diff.
fn assert_streams_equal(label: &str, off: &[String], on: &[String]) {
    let n = off.len().max(on.len());
    for i in 0..n {
        let (e, a) = (off.get(i), on.get(i));
        if e != a {
            panic!(
                "{label}: observable streams diverged at event {}:\n  \
                 compile-off: {}\n  compile-on:  {}",
                i + 1,
                e.map_or("<eof>", |s| s.as_str()),
                a.map_or("<eof>", |s| s.as_str()),
            );
        }
    }
}

fn assert_equiv(label: &str, cfg_off: ClusterConfig, cfg_on: ClusterConfig, jobs: Vec<JobSpec>) {
    let (off, tr_off) = run_cluster_traced(cfg_off, jobs.clone());
    let (on, tr_on) = run_cluster_traced(cfg_on, jobs);
    assert_results_equal(label, &off, &on);
    assert_streams_equal(label, &observable(&tr_off), &observable(&tr_on));
}

#[test]
fn compile_on_matches_off_across_seeds_and_features() {
    // The property sweep: every feature axis that interacts with macro
    // entry (preemption's victim scans and waiter wakes, the latency
    // model's probe protocol, admission's arrival-time verdicts,
    // interference's launch-time iv arithmetic), alone and combined,
    // over several arrival seeds.
    for seed in [3u64, 11, 42] {
        for &(label, preempt, latency, admit, interference) in &[
            ("plain", false, false, false, false),
            ("preempt", true, false, false, false),
            ("latency", false, true, false, false),
            ("admission", false, false, true, false),
            ("interference", false, false, false, true),
            ("everything", true, true, true, true),
        ] {
            let jobs = stream(seed, interference);
            assert_equiv(
                &format!("{label}/seed{seed}"),
                cfg(preempt, latency, admit, false),
                cfg(preempt, latency, admit, true),
                jobs,
            );
        }
    }
}

#[test]
fn admission_sweep_actually_rejects_somewhere() {
    // Guard against the admission axis of the property sweep going
    // vacuous: at least one swept seed must drive the token bucket to
    // an actual rejection.
    let any = [3u64, 11, 42].iter().any(|&seed| {
        run_cluster(cfg(false, false, true, false), stream(seed, false)).rejected > 0
    });
    assert!(any, "no swept seed triggered admission — tighten the bucket");
}

#[test]
fn macro_stepping_actually_collapses_events() {
    // Guard against the whole suite passing because macros never
    // enter. Four solo synthetic jobs on one 4-GPU node, batch at
    // t = 0: each job runs alone on its device, the steady-state
    // segment covers its whole trace body, and the compile-on run must
    // fire strictly fewer events than fine-grained stepping.
    let jobs: Vec<JobSpec> = (0..4)
        .map(|i| synthetic_job(&format!("solo{i}"), JobClass::Small, 1 << 30, 2_000_000, 0.0))
        .collect();
    let mk = |compile| ClusterConfig {
        cluster: ClusterSpec::single(NodeSpec::v100x4()),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 4,
        dispatch: "rr",
        preempt: None,
        latency: LatencyModel::off(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: compile,
    };
    let (off, tr_off) = run_cluster_traced(mk(false), jobs.clone());
    let (on, tr_on) = run_cluster_traced(mk(true), jobs);
    assert_results_equal("solo", &off, &on);
    assert_streams_equal("solo", &observable(&tr_off), &observable(&tr_on));
    assert!(
        on.events_fired < off.events_fired,
        "macro-stepping never engaged: {} events on vs {} off",
        on.events_fired,
        off.events_fired
    );
}

#[test]
fn sanitizer_stays_observational_under_macro_stepping() {
    // `--sanitize --compile-traces on`: the invariant checks must hold
    // mid-macro (segments only enter with their memory reservation in
    // place, so conservation is unperturbed) and the sanitized run's
    // results must equal both the unsanitized compile-on run and the
    // compile-off run bit-for-bit.
    let jobs = stream(5, false);
    let plain_on = run_cluster(cfg(false, false, false, true), jobs.clone());
    let (sanitized, report) = run_cluster_sanitized(cfg(false, false, false, true), jobs.clone());
    assert!(report.is_clean(), "sanitizer violations under macro-stepping: {:?}", report.violations);
    assert!(report.events_checked > 0);
    assert_eq!(plain_on.makespan, sanitized.makespan);
    assert_eq!(plain_on.events_fired, sanitized.events_fired);
    assert_eq!(plain_on.observable_events, sanitized.observable_events);
    let off = run_cluster(cfg(false, false, false, false), jobs);
    assert_results_equal("sanitized-vs-off", &off, &sanitized);
}

// ---- golden fixture, replayed in both modes --------------------------

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/compile_observable.trace")
}

#[test]
fn observable_golden_fixture_replays_in_both_modes() {
    // The committed observable stream of one reference scenario (W1 x
    // 4 nodes, open system, seed 7) must replay byte-for-byte with
    // compilation off AND on. Same fixture protocol as golden_trace.rs:
    // bootstrap-on-missing for dev convenience, hard failure in CI
    // unless bootstrapping is explicitly requested, UPDATE_GOLDEN=1
    // rewrites after an intentional engine change.
    let jobs = stream(7, false);
    let (_, tr_off) = run_cluster_traced(cfg(false, false, false, false), jobs.clone());
    let (_, tr_on) = run_cluster_traced(cfg(false, false, false, true), jobs);
    let obs = observable(&tr_off);
    assert!(!obs.is_empty(), "an open-system run must fire observable events");
    assert_streams_equal("fixture", &obs, &observable(&tr_on));

    let actual = obs.join("\n") + "\n";
    let path = fixture_path();
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !path.exists() {
        let ci = std::env::var_os("CI").is_some();
        let bootstrap_ok = std::env::var_os("MGB_BOOTSTRAP_GOLDEN").is_some();
        if !path.exists() && ci && !bootstrap_ok && std::env::var_os("UPDATE_GOLDEN").is_none() {
            panic!(
                "golden fixture missing in CI: {} (commit it, or set \
                 MGB_BOOTSTRAP_GOLDEN=1 to bootstrap deliberately)",
                path.display()
            );
        }
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, &actual).unwrap();
        eprintln!("golden: wrote {} ({} events)", path.display(), obs.len());
        return;
    }
    let expected = fs::read_to_string(&path).unwrap();
    if expected == actual {
        return;
    }
    fs::write(path.with_extension("trace.actual"), &actual).unwrap();
    let exp: Vec<String> = expected.lines().map(str::to_string).collect();
    assert_streams_equal("committed-fixture", &exp, &obs);
    unreachable!("streams differ only in trailing whitespace");
}

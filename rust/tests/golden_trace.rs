//! Golden-trace harness over the event-core's trace recorder: the full
//! fired-event stream of reference runs is serialised and compared
//! byte-for-byte — against committed fixtures (snapshot tests) and
//! across in-process re-runs (replay determinism). This is what turns
//! "the engine is deterministic / zero-latency is bit-identical" from
//! two ad-hoc equality tests into a checked property of every event
//! the engine fires.
//!
//! Fixture protocol: missing fixtures are bootstrapped (written and
//! reported) on first run; `UPDATE_GOLDEN=1` rewrites them after an
//! intentional engine change. On mismatch the harness writes
//! `<name>.trace.actual` next to the fixture (CI uploads these as
//! artifacts) and panics with the *first divergent event*, not a giant
//! string diff.

use mgb::coordinator::{
    run_cluster, run_cluster_sanitized, run_cluster_traced, run_cluster_traced_on_backend,
    ClusterConfig, JobSpec, SchedMode,
};
use mgb::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use mgb::workloads::{poisson_arrivals, synthetic_job, Workload};
use std::fs;
use std::path::PathBuf;

fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(format!("{name}.trace"))
}

/// First line where the two streams disagree (1-based), with both
/// sides ("<eof>" when one stream is a prefix of the other).
fn first_divergence(expected: &str, actual: &str) -> (usize, String, String) {
    let (mut ei, mut ai) = (expected.lines(), actual.lines());
    let mut n = 1;
    loop {
        match (ei.next(), ai.next()) {
            (Some(e), Some(a)) if e == a => n += 1,
            (e, a) => {
                return (
                    n,
                    e.unwrap_or("<eof>").to_string(),
                    a.unwrap_or("<eof>").to_string(),
                )
            }
        }
    }
}

fn check_golden(name: &str, lines: &[String]) {
    let actual = lines.join("\n") + "\n";
    let path = golden_path(name);
    if std::env::var_os("UPDATE_GOLDEN").is_some() || !path.exists() {
        // Bootstrap-on-missing is a dev convenience only: in CI a
        // missing fixture is a hard failure (someone deleted or forgot
        // to commit it) unless the workflow explicitly opts pass 1
        // into bootstrapping so pass 2 can verify its output.
        let ci = std::env::var_os("CI").is_some();
        let bootstrap_ok = std::env::var_os("MGB_BOOTSTRAP_GOLDEN").is_some();
        if !path.exists() && ci && !bootstrap_ok && std::env::var_os("UPDATE_GOLDEN").is_none() {
            panic!(
                "golden fixture missing in CI: {} (commit it, or set \
                 MGB_BOOTSTRAP_GOLDEN=1 to bootstrap deliberately)",
                path.display()
            );
        }
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(&path, &actual).unwrap();
        eprintln!("golden: wrote {} ({} events)", path.display(), lines.len());
        return;
    }
    let expected = fs::read_to_string(&path).unwrap();
    if expected == actual {
        let _ = fs::remove_file(path.with_extension("trace.actual"));
        return;
    }
    fs::write(path.with_extension("trace.actual"), &actual).unwrap();
    let (ln, e, a) = first_divergence(&expected, &actual);
    panic!(
        "golden trace '{name}' diverged at event {ln}:\n  expected: {e}\n  actual:   {a}\n\
         (wrote {name}.trace.actual for artifact upload; UPDATE_GOLDEN=1 regenerates)"
    );
}

fn cfg(nodes: usize, dispatch: &'static str, latency: LatencyModel) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), nodes),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 16,
        dispatch,
        preempt: None,
        latency,
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

/// W1/W2 mix; `rate` turns the batch into open-system traffic.
fn mix(id: &str, rate: Option<f64>) -> Vec<JobSpec> {
    let mut jobs = Workload::by_id(id).unwrap().jobs(7);
    if let Some(r) = rate {
        poisson_arrivals(&mut jobs, r, 7);
    }
    jobs
}

// ---- fixture snapshots (W1/W2 on 1- and 4-node clusters) -------------

#[test]
fn golden_w1_single_node_batch() {
    let (r, tr) = run_cluster_traced(cfg(1, "rr", LatencyModel::off()), mix("W1", None));
    assert_eq!(r.completed() + r.crashed(), 16);
    assert!(!tr.is_empty(), "a batch run fires events");
    check_golden("w1_1node_batch", &tr);
}

#[test]
fn golden_w1_four_node_open_system() {
    let (r, tr) =
        run_cluster_traced(cfg(4, "least", LatencyModel::off()), mix("W1", Some(0.5)));
    assert_eq!(r.completed() + r.crashed(), 16);
    check_golden("w1_4node_open", &tr);
}

#[test]
fn golden_w2_single_node_batch() {
    let (r, tr) = run_cluster_traced(cfg(1, "rr", LatencyModel::off()), mix("W2", None));
    assert_eq!(r.completed() + r.crashed(), 16);
    check_golden("w2_1node_batch", &tr);
}

#[test]
fn golden_w2_four_node_open_system() {
    let (r, tr) =
        run_cluster_traced(cfg(4, "least", LatencyModel::off()), mix("W2", Some(0.5)));
    assert_eq!(r.completed() + r.crashed(), 16);
    check_golden("w2_4node_open", &tr);
}

#[test]
fn golden_w1_four_node_interference() {
    // The interference-on fixture: the same W1 x 4-node open-system
    // construction as `golden_w1_four_node_open`, with per-benchmark
    // resource-pressure vectors stamped (`--interference`). Pins the
    // contention-aware device model's full event stream, and holds the
    // calendar backend to the heap reference on the interference path.
    let mut jobs = mix("W1", Some(0.5));
    mgb::workloads::assign_interference(&mut jobs);
    assert!(
        jobs.iter().any(|j| !j.trace.peak_interference().is_zero()),
        "W1 binds rodinia artifacts, so stamping must take"
    );
    let (r, tr) = run_cluster_traced(cfg(4, "least", LatencyModel::off()), jobs.clone());
    assert_eq!(r.completed() + r.crashed(), 16);
    let (_, th) =
        run_cluster_traced_on_backend(cfg(4, "least", LatencyModel::off()), jobs, "heap");
    if tr != th {
        let (ln, e, a) = first_divergence(&tr.join("\n"), &th.join("\n"));
        panic!("backends diverged on the interference path at event {ln}:\n  calendar: {e}\n  heap:     {a}");
    }
    check_golden("w1_4node_interference", &tr);
}

#[test]
fn interference_vectors_change_the_stream_zero_vectors_do_not() {
    // The on/off contract in one place. A dense single-node batch (16
    // jobs on 4 GPUs — co-residency guaranteed) must fire a *different*
    // stream once vectors are stamped: the model has to bite. And jobs
    // whose launches bind no known artifact keep zero vectors, so
    // `assign_interference` on them must replay the untouched stream
    // byte-for-byte.
    let (_, off) = run_cluster_traced(cfg(1, "rr", LatencyModel::off()), mix("W1", None));
    let mut stamped = mix("W1", None);
    mgb::workloads::assign_interference(&mut stamped);
    let (_, on) = run_cluster_traced(cfg(1, "rr", LatencyModel::off()), stamped);
    assert_ne!(on, off, "stamped vectors must perturb a co-scheduled batch");
    // Synthetic jobs bind no artifact: stamping is a no-op end to end.
    let jobs: Vec<JobSpec> = (0..8)
        .map(|i| {
            synthetic_job(
                &format!("s{i}"),
                mgb::coordinator::JobClass::Small,
                1 << 30,
                2_000_000,
                0.0,
            )
        })
        .collect();
    let mut stamped = jobs.clone();
    mgb::workloads::assign_interference(&mut stamped);
    let (_, a) = run_cluster_traced(cfg(1, "rr", LatencyModel::off()), jobs);
    let (_, b) = run_cluster_traced(cfg(1, "rr", LatencyModel::off()), stamped);
    assert_eq!(a, b, "zero vectors must replay the legacy stream exactly");
}

// ---- admission off-path bit-identity (PR 8 tentpole acceptance) ------

#[test]
fn admit_off_policy_replays_every_golden_stream_byte_identically() {
    // `--admit off` must be indistinguishable from "no admission config
    // at all" at event granularity: the exact committed fixtures replay
    // (check_golden compares byte-for-byte against the snapshots the
    // admit-None tests above pin), and no admission-layer event kind
    // ever crosses the queue on the off path.
    for (name, id, nodes, dispatch, rate) in [
        ("w1_1node_batch", "W1", 1usize, "rr", None),
        ("w1_4node_open", "W1", 4usize, "least", Some(0.5)),
        ("w2_1node_batch", "W2", 1usize, "rr", None),
        ("w2_4node_open", "W2", 4usize, "least", Some(0.5)),
    ] {
        let mut c = cfg(nodes, dispatch, LatencyModel::off());
        c.admit = Some(mgb::coordinator::AdmissionConfig { policy: "off", ..Default::default() });
        let (r, tr) = run_cluster_traced(c, mix(id, rate));
        assert_eq!(r.rejected, 0, "the off policy never rejects");
        assert_eq!(r.degraded, 0, "the off policy never degrades");
        for line in &tr {
            assert!(
                !line.contains("AdmitReject") && !line.contains("FrontendServe"),
                "off-path run fired an admission event: {line}"
            );
        }
        check_golden(name, &tr);
    }
}

// ---- sanitizer: clean on every golden scenario, results untouched ----

#[test]
fn sanitizer_reports_zero_violations_on_every_golden_scenario() {
    // The engine sanitizer re-checks memory conservation, worker-slot
    // uniqueness, and clock monotonicity after every fired event. On
    // the exact scenarios the golden fixtures pin it must find nothing
    // — and because it is observational, the sanitized run's results
    // must equal the plain run's bit-for-bit.
    for (id, nodes, dispatch, rate) in [
        ("W1", 1usize, "rr", None),
        ("W1", 4usize, "least", Some(0.5)),
        ("W2", 1usize, "rr", None),
        ("W2", 4usize, "least", Some(0.5)),
    ] {
        let jobs = mix(id, rate);
        let plain = run_cluster(cfg(nodes, dispatch, LatencyModel::off()), jobs.clone());
        let (sanitized, report) =
            run_cluster_sanitized(cfg(nodes, dispatch, LatencyModel::off()), jobs);
        assert!(
            report.is_clean(),
            "{id}/{nodes}n/{dispatch}: sanitizer violations: {:?}",
            report.violations
        );
        assert!(report.events_checked > 0);
        assert_eq!(plain.makespan, sanitized.makespan, "{id}/{nodes}n/{dispatch}");
        assert_eq!(plain.events_fired, sanitized.events_fired);
        for (x, y) in plain.jobs.iter().zip(&sanitized.jobs) {
            assert_eq!((x.started, x.ended, x.node, x.crashed), (y.started, y.ended, y.node, y.crashed));
        }
    }
}

// ---- backend equivalence (calendar queue vs BinaryHeap reference) ----

#[test]
fn calendar_backend_fires_byte_identical_streams_to_the_heap() {
    // The calendar queue replaces the `BinaryHeap` on the engine's hot
    // path; the heap survives as the reference backend precisely so
    // this test can demand byte-for-byte equality of the full fired-
    // event stream — which also pins the calendar backend to the same
    // committed golden fixtures as the heap, with no second fixture
    // set to maintain.
    for (nodes, dispatch, rate) in
        [(1usize, "rr", None), (4usize, "least", Some(0.5)), (2usize, "least", Some(2.0))]
    {
        let jobs = mix("W2", rate);
        let (a, ta) = run_cluster_traced(cfg(nodes, dispatch, LatencyModel::off()), jobs.clone());
        let (b, tb) =
            run_cluster_traced_on_backend(cfg(nodes, dispatch, LatencyModel::off()), jobs, "heap");
        if ta != tb {
            let (ln, e, act) = first_divergence(&ta.join("\n"), &tb.join("\n"));
            panic!("backends diverged ({nodes}n/{dispatch}) at event {ln}:\n  calendar: {e}\n  heap:     {act}");
        }
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.events_fired, b.events_fired);
        assert_eq!(a.peak_events, b.peak_events);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!((x.started, x.ended, x.node), (y.started, y.ended, y.node));
        }
    }
}

#[test]
fn backend_equivalence_holds_with_preemption_and_latency_on() {
    // Same contract under the densest event mix the engine has:
    // checkpoint/restart preemption plus a nonzero latency model, so
    // Ckpt*/Restart/Probe*/DispatchArrive kinds all cross the queue
    // (same-instant ties between them are where a queue-order bug
    // would hide).
    let lat = LatencyModel {
        probe_rtt_s: 0.01,
        dispatch_base_s: 0.05,
        frontend_service_s: 0.001,
        ..LatencyModel::default()
    };
    let mut c = cfg(2, "least", lat);
    c.preempt = Some(mgb::sched::PreemptConfig::default());
    let jobs = mix("W1", Some(2.0));
    let (a, ta) = run_cluster_traced(c.clone(), jobs.clone());
    let (b, tb) = run_cluster_traced_on_backend(c, jobs, "heap");
    if ta != tb {
        let (ln, e, act) = first_divergence(&ta.join("\n"), &tb.join("\n"));
        panic!("backends diverged at event {ln}:\n  calendar: {e}\n  heap:     {act}");
    }
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.makespan, b.makespan);
}

// ---- zero-latency bit-identity (the tentpole's acceptance) -----------

#[test]
fn zero_latency_pushes_no_probe_or_dispatch_events() {
    // An all-zero model — including one that is only *elementwise* zero
    // (explicit per-node zeros) — must take the exact pre-latency code
    // paths: the event streams are byte-identical and contain none of
    // the latency kinds.
    for (nodes, dispatch) in [(1usize, "rr"), (4usize, "least")] {
        let jobs = mix("W1", Some(0.5));
        let (a, ta) = run_cluster_traced(cfg(nodes, dispatch, LatencyModel::off()), jobs.clone());
        let zeroed = LatencyModel { per_node_rtt_s: vec![0.0; nodes], ..LatencyModel::off() };
        let (b, tb) = run_cluster_traced(cfg(nodes, dispatch, zeroed), jobs);
        assert_eq!(ta, tb, "all-zero model must replay the off engine exactly");
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.started, y.started);
            assert_eq!(x.ended, y.ended);
            assert_eq!(x.node, y.node);
        }
        for line in &ta {
            assert!(
                !line.contains("ProbeSent")
                    && !line.contains("ProbeAck")
                    && !line.contains("DispatchArrive")
                    && !line.contains("ReProbe"),
                "zero-latency run fired a latency event: {line}"
            );
        }
    }
}

#[test]
fn traces_replay_byte_identical_run_to_run() {
    // Replay determinism at event granularity, with the latency layer
    // exercised too (nonzero model => Probe*/DispatchArrive present).
    let jobs = mix("W2", Some(0.5));
    let lat = LatencyModel {
        probe_rtt_s: 0.01,
        dispatch_base_s: 0.05,
        frontend_service_s: 0.001,
        ..LatencyModel::default()
    };
    let (a, ta) = run_cluster_traced(cfg(2, "least", lat.clone()), jobs.clone());
    let (b, tb) = run_cluster_traced(cfg(2, "least", lat), jobs);
    assert_eq!(ta, tb, "same config + seed must fire the same events");
    assert_eq!(a.makespan, b.makespan);
    assert!(
        ta.iter().any(|l| l.contains("ProbeSent"))
            && ta.iter().any(|l| l.contains("ProbeAck"))
            && ta.iter().any(|l| l.contains("DispatchArrive")),
        "nonzero model must route through the probe protocol"
    );
}

// ---- latency semantics ----------------------------------------------

#[test]
fn nonzero_latency_delays_admission_by_the_round_trip() {
    // One job, one node: it must land (worker pickup = `started`)
    // exactly one probe RTT + one dispatch cost after arrival, and its
    // first task additionally pays a task-probe round-trip.
    let lat = LatencyModel {
        probe_rtt_s: 0.5,
        dispatch_base_s: 0.25,
        ..LatencyModel::default()
    };
    let job = synthetic_job("j", mgb::coordinator::JobClass::Small, 1 << 20, 1_000_000, 0.0);
    let off = run_cluster(cfg(1, "rr", LatencyModel::off()), vec![job.clone()]);
    let on = run_cluster(cfg(1, "rr", lat), vec![job]);
    assert_eq!(on.completed(), 1);
    let (o, z) = (&on.jobs[0], &off.jobs[0]);
    assert_eq!(z.started, 0.0);
    assert!((o.started - 0.75).abs() < 1e-12, "started {} != rtt+dispatch", o.started);
    // Ended: shifted by admission delay plus one task-probe RTT.
    let want = z.ended + 0.75 + 0.5;
    assert!((o.ended - want).abs() < 1e-9, "ended {} want {want}", o.ended);
}

#[test]
fn frontend_queueing_serialises_simultaneous_arrivals() {
    // Two jobs arrive at t = 0 with a 0.1 s frontend service time and
    // otherwise-free RPCs: the second routing probe is served 0.1 s
    // after the first, so the second job lands 0.1 s later.
    let lat = LatencyModel { frontend_service_s: 0.1, ..LatencyModel::default() };
    let jobs = vec![
        synthetic_job("a", mgb::coordinator::JobClass::Small, 1 << 20, 1_000_000, 0.0),
        synthetic_job("b", mgb::coordinator::JobClass::Small, 1 << 20, 1_000_000, 0.0),
    ];
    let r = run_cluster(cfg(1, "rr", lat), jobs);
    assert_eq!(r.completed(), 2);
    assert_eq!(r.jobs[0].started, 0.0);
    assert!((r.jobs[1].started - 0.1).abs() < 1e-12, "b started {}", r.jobs[1].started);
}

#[test]
fn stale_routing_uses_probe_time_snapshot() {
    // The race the latency model exists to expose. Two 1xV100 nodes,
    // least-loaded dispatch. J0 (0.5 s of work) is routed to node 0 at
    // t=0. J1 arrives at t=1: its probe-time snapshot still shows J0
    // outstanding on node 0, so J1 routes to node 1 — even though J0
    // finishes (~2.7 s) before J1 lands (t=3.1), at which instant an
    // instant-landing router would have picked node 0. The engine must
    // keep the probe-time decision.
    let lat = LatencyModel {
        probe_rtt_s: 0.1,
        dispatch_base_s: 2.0,
        ..LatencyModel::default()
    };
    let two_nodes = |latency: LatencyModel| ClusterConfig {
        cluster: ClusterSpec::homogeneous(
            NodeSpec {
                gpus: vec![mgb::gpu::GpuSpec::v100()],
                cpu_cores: 8,
                name: "1xV100".into(),
            },
            2,
        ),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 2,
        dispatch: "least",
        preempt: None,
        latency,
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let class = mgb::coordinator::JobClass::Small;
    let jobs = vec![
        synthetic_job("j0", class, 1 << 20, 500_000, 0.0),
        synthetic_job("j1", class, 1 << 20, 1_000_000, 1.0),
    ];
    let r = run_cluster(two_nodes(lat), jobs);
    assert_eq!(r.completed(), 2);
    assert_eq!(r.jobs[0].node, 0, "J0 takes the tie-break node");
    assert!(r.jobs[0].ended < 3.1, "J0 must finish before J1 lands: {}", r.jobs[0].ended);
    assert_eq!(
        r.jobs[1].node, 1,
        "stale probe-time snapshot routes J1 away from J0's node"
    );
    // Contrast: the instant-landing router. With latency off and J1
    // arriving at its *landing* instant, node 0 is long idle again and
    // wins the tie-break — a different decision from the same landing
    // time, which is exactly what "stale" means.
    let jobs = vec![
        synthetic_job("j0", class, 1 << 20, 500_000, 0.0),
        synthetic_job("j1", class, 1 << 20, 1_000_000, 3.1),
    ];
    let r = run_cluster(two_nodes(LatencyModel::off()), jobs);
    assert_eq!(r.jobs[1].node, 0, "instant routing at landing time picks node 0");
}

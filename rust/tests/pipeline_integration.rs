//! Integration across the whole front half: IR authoring → compiler →
//! lazy runtime → batch coordinator under every scheduler, plus bench
//! harness smoke runs. (The offline crate set has no criterion/proptest;
//! rust/tests/property.rs carries the randomized invariants.)

use mgb::bench_harness;
use mgb::coordinator::{run_batch, RunConfig, SchedMode};
use mgb::gpu::{InterferenceProfile, NodeSpec};
use mgb::workloads::{nn_mix, Workload, COMBOS, NN_TASKS, WORKLOADS};

#[test]
fn every_workload_trace_is_well_formed() {
    for c in &COMBOS {
        c.job_spec().trace.check_well_formed().unwrap();
    }
    for t in NN_TASKS {
        t.job_spec().trace.check_well_formed().unwrap();
    }
}

#[test]
fn jobs_are_conserved_under_every_scheduler() {
    let jobs = Workload::by_id("W1").unwrap().jobs(7);
    let node = NodeSpec::v100x4();
    for mode in [
        SchedMode::Sa,
        SchedMode::Cg,
        SchedMode::Policy("mgb2"),
        SchedMode::Policy("mgb3"),
        SchedMode::Policy("schedgpu"),
    ] {
        let r = run_batch(RunConfig { node: node.clone(), mode: mode.clone(), workers: 8 }, jobs.clone());
        assert_eq!(
            r.completed() + r.crashed(),
            jobs.len(),
            "{mode:?}: done+crashed must equal submitted"
        );
        for j in &r.jobs {
            assert!(j.ended >= j.started, "{mode:?}: causality");
            assert!(j.ended <= r.makespan + 1e-9, "{mode:?}: makespan covers all jobs");
        }
    }
}

#[test]
fn probe_carrying_schedulers_never_crash() {
    // Memory safety is MGB's core guarantee (§III-B): across all eight
    // paper workloads and both nodes, no MGB/schedGPU job may OOM.
    for node in [NodeSpec::p100x2(), NodeSpec::v100x4()] {
        for w in WORKLOADS {
            let jobs = w.jobs(3);
            for policy in ["mgb2", "mgb3", "schedgpu"] {
                let r = run_batch(
                    RunConfig {
                        node: node.clone(),
                        mode: SchedMode::Policy(policy),
                        workers: bench_harness::mgb_workers(&node),
                    },
                    jobs.clone(),
                );
                assert_eq!(r.crashed(), 0, "{policy} crashed on {} {}", node.name, w.id);
            }
        }
    }
}

#[test]
fn sa_never_crashes_and_never_slows_kernels() {
    for w in WORKLOADS.iter().take(4) {
        let r = run_batch(
            RunConfig { node: NodeSpec::p100x2(), mode: SchedMode::Sa, workers: 0 },
            w.jobs(11),
        );
        assert_eq!(r.crashed(), 0);
        assert!(r.kernel_slowdown_pct().abs() < 0.01, "dedicated devices: no interference");
    }
}

#[test]
fn turnaround_at_least_dedicated_wall_time() {
    let jobs = Workload::by_id("W2").unwrap().jobs(5);
    let r = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 16 },
        jobs,
    );
    for j in &r.jobs {
        assert!(
            j.turnaround() + 1e-9 >= j.kernel_dedicated_s,
            "{}: turnaround {} < dedicated kernel time {}",
            j.name,
            j.turnaround(),
            j.kernel_dedicated_s
        );
    }
}

#[test]
fn nn_mix_scales_to_128_jobs_deterministically() {
    let jobs = nn_mix(128, 9);
    let cfg = RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 32 };
    let a = run_batch(cfg.clone(), jobs.clone());
    let b = run_batch(cfg, jobs);
    assert_eq!(a.completed(), 128);
    assert_eq!(a.makespan, b.makespan, "replays must be bit-identical");
}

#[test]
fn bench_harness_experiments_all_run() {
    for exp in ["fig4", "fig6", "nn128", "cluster"] {
        let r = bench_harness::run_experiment(exp, 1).unwrap();
        assert!(!r.lines.is_empty(), "{exp} produced no rows");
    }
    assert!(bench_harness::run_experiment("nonsense", 1).is_none());
}

#[test]
fn paper_shapes_hold_end_to_end() {
    // The coarse reproduction claims, asserted as a regression net:
    // MGB beats SA on throughput on every 16-job workload; Alg3's
    // kernel slowdown stays single-digit.
    let node = NodeSpec::v100x4();
    for w in WORKLOADS.iter().filter(|w| w.n_jobs == 16) {
        let jobs = w.jobs(bench_harness::DEFAULT_SEED);
        let sa = run_batch(RunConfig { node: node.clone(), mode: SchedMode::Sa, workers: 0 }, jobs.clone());
        let mgb = run_batch(
            RunConfig { node: node.clone(), mode: SchedMode::Policy("mgb3"), workers: 16 },
            jobs,
        );
        let speedup = mgb.throughput() / sa.throughput();
        assert!(speedup > 1.3, "{}: MGB only {speedup:.2}x SA", w.id);
        assert!(mgb.kernel_slowdown_pct() < 10.0, "{}: slowdown too high", w.id);
    }
}

#[test]
fn cg_crash_cleanup_releases_memory_for_survivors() {
    // Failure injection: an OOM-crashing CG batch must still complete
    // every job that survives, and later jobs must be able to use the
    // memory the crashed ones released (no leak: the batch drains).
    use mgb::coordinator::JobClass;
    use mgb::lazy::{JobTrace, TaskResources, TraceEvent};
    let mk = |mem: u64| {
        let res = TaskResources {
            static_dev: None,
            mem_bytes: mem,
            heap_bytes: 0,
            grid: 100,
            block: 32,
            written_bytes: mem,
            iv: InterferenceProfile::ZERO,
        };
        JobTrace::new(vec![
            TraceEvent::TaskBegin { task: 0, res },
            TraceEvent::Malloc { task: 0, bytes: mem },
            TraceEvent::Launch {
                task: 0,
                kernel: "k".into(),
                artifact: None,
                grid: 100,
                block: 32,
                work_us: 1_000_000,
            },
            TraceEvent::Free { task: 0, bytes: mem },
            TraceEvent::TaskEnd { task: 0 },
        ])
    };
    // 8 jobs of 9 GB on ONE 16 GB device, 4 pinned workers: first two
    // co-resident jobs fit 9+? -> second malloc OOMs; survivors keep
    // draining the queue afterwards.
    let node = NodeSpec {
        gpus: vec![mgb::gpu::GpuSpec::v100()],
        cpu_cores: 8,
        name: "1xV100".into(),
    };
    let jobs: Vec<_> = (0..8)
        .map(|i| mgb::coordinator::JobSpec {
            name: format!("j{i}"),
            class: JobClass::Large,
            trace: mk(9 << 30),
            arrival: 0.0,
            slo: None,
        })
        .collect();
    let r = run_batch(RunConfig { node, mode: SchedMode::Cg, workers: 4 }, jobs);
    assert_eq!(r.completed() + r.crashed(), 8);
    assert!(r.crashed() > 0, "9+9 GB co-resident must OOM");
    assert!(r.completed() > 0, "survivors must finish after crashes free memory");
    // Every completed job actually ran its kernel.
    for j in r.jobs.iter().filter(|j| !j.crashed) {
        assert_eq!(j.n_kernels, 1, "{}", j.name);
    }
}

#[test]
fn dead_allocation_never_reaches_a_device() {
    // Lazy-runtime edge: a buffer malloc'd and freed without any launch
    // binds to no task and must not appear in the trace at all.
    use mgb::compiler::compile;
    use mgb::ir::{Expr, ProgramBuilder};
    use mgb::lazy::interpret;
    let mut pb = ProgramBuilder::new();
    let dead = pb.declare("dead_alloc", 1);
    pb.define(dead, |f| {
        let n = f.param(0);
        // a loop so the helper is NOT inlined -> lazy path
        f.loop_n(n, |f| {
            f.c(0);
        });
        let sz = f.assign(Expr::v(n).mul(Expr::c(1024)));
        let b = f.malloc(sz);
        f.h2d(b, sz);
        f.free(b);
    });
    pb.func("main", 1, |f| {
        let n = f.param(0);
        f.call(dead, &[n]);
    });
    let trace = interpret(&compile(&pb.finish()), &[16]).unwrap();
    trace.check_well_formed().unwrap();
    assert_eq!(trace.n_tasks(), 0, "no kernel launch -> no GPU task");
    assert!(trace.events.is_empty(), "nothing to execute: {:?}", trace.events);
}

#[test]
fn zero_worker_config_still_terminates() {
    let jobs = Workload::by_id("W1").unwrap().jobs(1);
    // workers clamps to >= 1 — the batch must drain, not hang.
    let r = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 0 },
        jobs,
    );
    assert_eq!(r.completed(), 16);
}

#[test]
fn empty_batch_is_a_clean_noop() {
    let r = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 4 },
        vec![],
    );
    assert_eq!(r.completed(), 0);
    assert_eq!(r.makespan, 0.0);
}

#[test]
fn single_job_larger_than_any_gpu_crashes_everywhere() {
    // A 20 GB job cannot run on 16 GB devices: CG/SA crash it; MGB's
    // probe can never place it — the coordinator must fail it rather
    // than deadlock the batch.
    use mgb::coordinator::JobClass;
    use mgb::lazy::{JobTrace, TaskResources, TraceEvent};
    let res = TaskResources {
        static_dev: None,
        mem_bytes: 20 << 30,
        heap_bytes: 0,
        grid: 10,
        block: 32,
        written_bytes: 20 << 30,
        iv: InterferenceProfile::ZERO,
    };
    let job = mgb::coordinator::JobSpec {
        name: "whale".into(),
        class: JobClass::Large,
        arrival: 0.0,
        slo: None,
        trace: JobTrace::new(vec![
            TraceEvent::TaskBegin { task: 0, res },
            TraceEvent::Malloc { task: 0, bytes: res.mem_bytes },
            TraceEvent::TaskEnd { task: 0 },
        ]),
    };
    let cg = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Cg, workers: 4 },
        vec![job.clone()],
    );
    assert_eq!(cg.crashed(), 1);
    let mgb = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 4 },
        vec![job],
    );
    assert_eq!(mgb.crashed(), 1, "unplaceable job must be failed, not reported done");
}

#[test]
fn arrivals_gate_job_starts() {
    // Open-system extension: a job must not start before it arrives,
    // and idle workers must pick it up when it does.
    let mut jobs = Workload::by_id("W1").unwrap().jobs(2);
    jobs.truncate(4);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.arrival = 50.0 * i as f64;
    }
    let r = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 8 },
        jobs,
    );
    assert_eq!(r.completed(), 4);
    for (i, j) in r.jobs.iter().enumerate() {
        let arrival = 50.0 * i as f64;
        assert!(j.started + 1e-9 >= arrival, "{}: started {} before arrival {arrival}", j.name, j.started);
        // plenty of idle workers: pickup is immediate on arrival
        assert!(j.started - arrival < 1e-6, "{}: pickup delayed", j.name);
        assert!(j.turnaround() > 0.0 && j.turnaround() <= j.ended + 1e-9);
    }
}

#[test]
fn static_mapping_honours_set_device_and_can_oom() {
    // Paper §II-B / Fig. 1: two apps statically map their memory-heavy
    // kernels to device 1 via cudaSetDevice; co-executing them OOMs,
    // while MGB ignores the static binding and packs safely.
    use mgb::compiler::compile;
    use mgb::coordinator::JobClass;
    use mgb::ir::{Expr, ProgramBuilder};
    use mgb::lazy::interpret;
    let app = |mem_gib: i64| {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 0, |f| {
            let d1 = f.c(1);
            f.set_device(d1); // "my memory-heavy kernel goes to device 1"
            let sz = f.assign(Expr::c(mem_gib << 30));
            let a = f.malloc(sz);
            f.h2d(a, sz);
            let g = f.c(64);
            let b = f.c(128);
            let w = f.c(3_000_000);
            f.launch("heavy", g, b, &[a], w);
            f.free(a);
        });
        let trace = interpret(&compile(&pb.finish()), &[]).unwrap();
        // the probe must carry the static binding
        let begin = trace.events.iter().find_map(|e| match e {
            mgb::lazy::TraceEvent::TaskBegin { res, .. } => Some(*res),
            _ => None,
        });
        assert_eq!(begin.unwrap().static_dev, Some(1));
        mgb::coordinator::JobSpec {
            name: format!("app-{mem_gib}g"),
            class: JobClass::Large,
            trace,
            arrival: 0.0,
            slo: None,
        }
    };
    let jobs = vec![app(10), app(9)];
    let st = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Static, workers: 2 },
        jobs.clone(),
    );
    assert_eq!(st.crashed(), 1, "10+9 GB both statically on device 1: OOM");
    let mgb = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Policy("mgb3"), workers: 2 },
        jobs,
    );
    assert_eq!(mgb.crashed(), 0, "MGB overrides the static binding");
}

#[test]
fn default_device0_without_set_device() {
    use mgb::compiler::compile;
    use mgb::coordinator::JobClass;
    use mgb::ir::{Expr, ProgramBuilder};
    use mgb::lazy::interpret;
    // Two 9 GB apps that never call cudaSetDevice: CUDA defaults both
    // to device 0 -> OOM under static mode even on a 4-GPU node.
    let app = |i: usize| {
        let mut pb = ProgramBuilder::new();
        pb.func("main", 0, |f| {
            let sz = f.assign(Expr::c(9i64 << 30));
            let a = f.malloc(sz);
            let g = f.c(64);
            let b = f.c(128);
            let w = f.c(1_000_000);
            f.launch("k", g, b, &[a], w);
            f.free(a);
        });
        mgb::coordinator::JobSpec {
            name: format!("app{i}"),
            class: JobClass::Large,
            trace: interpret(&compile(&pb.finish()), &[]).unwrap(),
            arrival: 0.0,
            slo: None,
        }
    };
    let r = run_batch(
        RunConfig { node: NodeSpec::v100x4(), mode: SchedMode::Static, workers: 2 },
        vec![app(0), app(1)],
    );
    assert_eq!(r.crashed(), 1, "both default to device0");
}

#[test]
fn gir_fixtures_parse_compile_and_run() {
    use mgb::compiler::compile;
    use mgb::ir::parse::parse_program;
    use mgb::lazy::interpret;
    for (path, text) in [
        ("vecadd.gir", include_str!("../../examples/ir/vecadd.gir")),
        ("static_mapping.gir", include_str!("../../examples/ir/static_mapping.gir")),
    ] {
        let p = parse_program(text).unwrap_or_else(|e| panic!("{path}: {e:#}"));
        let c = compile(&p);
        assert!(!c.tasks.is_empty(), "{path}: no tasks");
        let trace = interpret(&c, &[1 << 20]).unwrap();
        trace.check_well_formed().unwrap();
        // Display -> parse round-trip
        let p2 = parse_program(&p.to_string()).unwrap();
        assert_eq!(p.to_string(), p2.to_string(), "{path}: display round-trip");
    }
}

//! Edge coverage for the PR-4 latency-layer protocol: the timeout +
//! re-probe guard on stale routing decisions, daemon-side probe
//! coalescing, and the latency-aware dispatcher's zero-RTT degeneration
//! to least-loaded. Companion to the PR-3 semantics tests in
//! `golden_trace.rs` (stale snapshots, admission delays, queueing).

use mgb::coordinator::{
    run_cluster, run_cluster_traced, ClusterConfig, JobClass, JobSpec, SchedMode,
};
use mgb::gpu::{ClusterSpec, GpuSpec, LatencyModel, NodeSpec};
use mgb::workloads::{poisson_arrivals, synthetic_job, Workload};

fn v100x1() -> NodeSpec {
    NodeSpec { gpus: vec![GpuSpec::v100()], cpu_cores: 8, name: "1xV100".into() }
}

fn two_small_nodes(dispatch: &'static str, latency: LatencyModel) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(v100x1(), 2),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 2,
        dispatch,
        preempt: None,
        latency,
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

/// The PR-3 stale-routing race, re-probe off: RTT 0.1 s, dispatch hop
/// 2.0 s, so every routing decision is stale by 2.1 s when it lands.
fn race_model() -> LatencyModel {
    LatencyModel { probe_rtt_s: 0.1, dispatch_base_s: 2.0, ..LatencyModel::default() }
}

/// J0 (0.5 s of work) at t=0 and J1 at t=1: J1's probe-time snapshot
/// shows J0 on node 0, so PR-3 routes J1 to node 1 even though J0 is
/// long gone by the time J1 lands at t=3.1.
fn race_jobs() -> Vec<JobSpec> {
    vec![
        synthetic_job("j0", JobClass::Small, 1 << 20, 500_000, 0.0),
        synthetic_job("j1", JobClass::Small, 1 << 20, 1_000_000, 1.0),
    ]
}

#[test]
fn reprobe_fires_exactly_at_the_staleness_bound_and_redirects() {
    // Staleness bound 1.8 s < landing delay 2.1 s: every routing is
    // guarded. J1 is routed to node 1 at t=1.0; its re-probe fires at
    // exactly t = 1.0 + 1.8 = 2.8, *after* J0 finished (~2.70), so the
    // fresh snapshot shows two idle nodes and the tie-break redirects
    // J1 to node 0. The redirected journey restarts at the re-probe
    // instant: J1 lands at 2.8 + 0.1 + 2.0 = 4.9 — the landing time
    // itself encodes that the guard fired at the bound, not before or
    // after.
    let lat = LatencyModel { reprobe_after_s: 1.8, reprobe_budget: 1, ..race_model() };
    let r = run_cluster(two_small_nodes("least", lat), race_jobs());
    assert_eq!(r.completed(), 2);
    assert_eq!(r.jobs[0].node, 0);
    assert!(r.jobs[0].ended < 2.8, "J0 must be gone before the re-probe fires");
    assert_eq!(r.jobs[1].node, 0, "re-probe redirects J1 onto the now-idle node 0");
    assert!(
        (r.jobs[1].started - 4.9).abs() < 1e-9,
        "redirected landing = arrival + bound + RTT + dispatch, got {}",
        r.jobs[1].started
    );
    // Contrast: without the guard the stale decision stands (the PR-3
    // race test), landing on node 1 at t=3.1.
    let r = run_cluster(two_small_nodes("least", race_model()), race_jobs());
    assert_eq!(r.jobs[1].node, 1, "unguarded routing keeps the stale pick");
    assert!((r.jobs[1].started - 3.1).abs() < 1e-9);
}

#[test]
fn reprobe_confirmation_commits_the_original_landing_time() {
    // Same race, but J0 runs 5 s — still resident on node 0 when J1's
    // re-probe fires at t=2.8. The fresh snapshot agrees with the
    // original decision (node 1), and a confirming re-probe must not
    // cost anything: every observable of the run equals the unguarded
    // engine's, bit for bit.
    let jobs = vec![
        synthetic_job("j0", JobClass::Small, 1 << 20, 5_000_000, 0.0),
        synthetic_job("j1", JobClass::Small, 1 << 20, 1_000_000, 1.0),
    ];
    let lat = LatencyModel { reprobe_after_s: 1.8, reprobe_budget: 1, ..race_model() };
    let guarded = run_cluster(two_small_nodes("least", lat), jobs.clone());
    let plain = run_cluster(two_small_nodes("least", race_model()), jobs);
    assert_eq!(guarded.completed(), 2);
    assert_eq!(guarded.jobs[1].node, 1, "confirmation keeps the original route");
    assert_eq!(guarded.makespan, plain.makespan);
    for (g, p) in guarded.jobs.iter().zip(&plain.jobs) {
        assert_eq!(g.node, p.node);
        assert_eq!(g.started, p.started, "{}: confirmation must not delay landing", g.name);
        assert_eq!(g.ended, p.ended);
    }
}

#[test]
fn reprobe_budget_exhaustion_falls_back_to_the_original_route() {
    // Budget 0 disables the guard outright, whatever the staleness
    // bound: the whole run — every fired event — must be byte-identical
    // to the re-probe-free engine (the "routing always terminates"
    // bound degenerating to PR-3 behaviour).
    let lat = LatencyModel { reprobe_after_s: 1.8, reprobe_budget: 0, ..race_model() };
    let (exhausted, te) = run_cluster_traced(two_small_nodes("least", lat), race_jobs());
    let (plain, tp) = run_cluster_traced(two_small_nodes("least", race_model()), race_jobs());
    assert_eq!(te, tp, "budget 0 must replay the unguarded engine exactly");
    assert_eq!(exhausted.jobs[1].node, plain.jobs[1].node);
    assert_eq!(exhausted.makespan, plain.makespan);
    assert!(
        !te.iter().any(|l| l.contains("ReProbe")),
        "no budget, no ReProbe events"
    );
}

#[test]
fn reprobe_never_arms_over_load_oblivious_round_robin() {
    // Round-robin never reads the load snapshot, so its decisions
    // cannot go stale — and re-asking it would fake a redirect on
    // every firing (the cursor has moved on), restarting journeys and
    // skewing the cycle. With rr the guard must stay dormant: the run
    // replays the unguarded engine byte-for-byte.
    let lat = LatencyModel { reprobe_after_s: 0.5, reprobe_budget: 3, ..race_model() };
    let (a, ta) = run_cluster_traced(two_small_nodes("rr", lat), race_jobs());
    let (b, tb) = run_cluster_traced(two_small_nodes("rr", race_model()), race_jobs());
    assert_eq!(ta, tb, "rr + re-probe must replay plain rr exactly");
    assert!(!ta.iter().any(|l| l.contains("ReProbe")), "no guard over rr");
    assert_eq!(a.makespan, b.makespan);
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(x.node, y.node, "{}: rr cycle undisturbed", x.name);
        assert_eq!(x.ended, y.ended);
    }
}

#[test]
fn reprobe_chain_is_bounded_by_the_budget() {
    // A generous budget against an open stream: the run must terminate,
    // complete everything, and replay deterministically — the per-job
    // budget is what keeps redirect chains finite.
    let mut jobs = Workload::by_id("W1").unwrap().jobs(7);
    poisson_arrivals(&mut jobs, 0.5, 7);
    let lat = LatencyModel {
        reprobe_after_s: 0.05,
        reprobe_budget: 4,
        probe_rtt_s: 0.1,
        dispatch_base_s: 1.0,
        frontend_service_s: 0.001,
        ..LatencyModel::default()
    };
    let cfg = || ClusterConfig {
        cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), 4),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 16,
        dispatch: "least",
        preempt: None,
        latency: lat.clone(),
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let (a, ta) = run_cluster_traced(cfg(), jobs.clone());
    let (b, tb) = run_cluster_traced(cfg(), jobs);
    assert_eq!(a.completed() + a.crashed(), 16, "every job resolves");
    assert_eq!(ta, tb, "guarded routing replays bit-for-bit");
    assert_eq!(a.makespan, b.makespan);
    let fired = ta.iter().filter(|l| l.contains("ReProbe")).count();
    assert!(fired > 0, "the scenario must actually exercise the guard");
    // Each served re-probe spends budget; a firing that finds the
    // frontend busy defers itself exactly once, so at most two ReProbe
    // events appear per unit of budget.
    assert!(fired <= 2 * 4 * 16, "budget bounds total re-probes");
}

#[test]
fn coalesced_probes_share_one_probe_ack() {
    // Two jobs land on one node at the same instant and send their task
    // probes together. Uncoalesced, each probe's reply is its own
    // ProbeAck (4 acks total: 2 routing + 2 task). With a coalescing
    // window the daemon holds the first reply, the second success joins
    // the open window, and ONE shared ProbeAck resumes both jobs.
    let jobs = || {
        vec![
            synthetic_job("a", JobClass::Small, 1 << 30, 1_000_000, 0.0),
            synthetic_job("b", JobClass::Small, 1 << 30, 1_000_000, 0.0),
        ]
    };
    let cfg = |coalesce_window_s: f64| ClusterConfig {
        cluster: ClusterSpec::single(NodeSpec::v100x4()),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 2,
        dispatch: "rr",
        preempt: None,
        latency: LatencyModel {
            probe_rtt_s: 0.1,
            coalesce_window_s,
            ..LatencyModel::default()
        },
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    let (plain, tp) = run_cluster_traced(cfg(0.0), jobs());
    let (coal, tc) = run_cluster_traced(cfg(0.05), jobs());
    let acks = |t: &[String]| t.iter().filter(|l| l.contains("ProbeAck")).count();
    assert_eq!(acks(&tp), 4, "uncoalesced: one reply per probe");
    assert_eq!(acks(&tc), 3, "coalesced: the two task probes share one reply");
    assert_eq!(plain.completed(), 2);
    assert_eq!(coal.completed(), 2);
    // The shared reply departs at window close: both jobs resume the
    // probe at t = landing(0.1) + window(0.05) + RTT(0.1) = 0.25, so
    // both end at the same instant, 0.05 s later than uncoalesced.
    for (c, p) in coal.jobs.iter().zip(&plain.jobs) {
        assert!((c.ended - (p.ended + 0.05)).abs() < 1e-9, "{}: {} vs {}", c.name, c.ended, p.ended);
    }
    assert_eq!(coal.jobs[0].ended, coal.jobs[1].ended, "batch members resume together");
}

#[test]
fn latency_dispatcher_at_zero_rtt_is_bit_identical_to_least() {
    // The degeneration contract: with every landing delay zero the
    // latency-aware dispatcher must *be* least-loaded — same event
    // stream, whether the latency model is fully off (zero-latency
    // paths) or on with only a frontend-service term (probe events
    // fire, but all delays that could differentiate nodes are zero).
    let mut jobs = Workload::by_id("W2").unwrap().jobs(7);
    poisson_arrivals(&mut jobs, 0.5, 7);
    let cfg = |dispatch: &'static str, latency: LatencyModel| ClusterConfig {
        cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), 4),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 16,
        dispatch,
        preempt: None,
        latency,
        admit: None,
        frontend_q: "fifo",
        compile_traces: false,
    };
    for model in [
        LatencyModel::off(),
        LatencyModel { frontend_service_s: 0.01, ..LatencyModel::default() },
    ] {
        let (a, ta) = run_cluster_traced(cfg("least", model.clone()), jobs.clone());
        let (b, tb) = run_cluster_traced(cfg("latency", model), jobs.clone());
        assert_eq!(ta, tb, "zero-RTT latency-aware must replay least exactly");
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.node, y.node);
            assert_eq!(x.ended, y.ended);
        }
        assert_eq!(b.dispatcher, "latency", "the name still reports the selection");
    }
}

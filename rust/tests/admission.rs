//! Admission-layer edge cases (PR 8 satellite): the off-path
//! bit-identity contract at event granularity, the exactly-at-capacity
//! token bucket, class-ordered shedding under a same-instant burst, and
//! the rejected-jobs-hold-nothing invariant.

use mgb::coordinator::{
    run_cluster, run_cluster_traced, AdmissionConfig, ClusterConfig, JobClass, JobSpec, SchedMode,
};
use mgb::gpu::{ClusterSpec, LatencyModel, NodeSpec};
use mgb::sched::SloClass;
use mgb::workloads::{poisson_arrivals, synthetic_job, Workload};

fn cfg(admit: Option<AdmissionConfig>) -> ClusterConfig {
    ClusterConfig {
        cluster: ClusterSpec::homogeneous(NodeSpec::v100x4(), 1),
        mode: SchedMode::Policy("mgb3"),
        workers_per_node: 8,
        dispatch: "rr",
        preempt: None,
        latency: LatencyModel::off(),
        admit,
        frontend_q: "fifo",
        compile_traces: false,
    }
}

fn token(rate_per_s: f64, burst: f64) -> Option<AdmissionConfig> {
    Some(AdmissionConfig { policy: "token", rate_per_s, burst, ..Default::default() })
}

fn job(name: &str, slo: Option<SloClass>, arrival: f64) -> JobSpec {
    let mut j = synthetic_job(name, JobClass::Small, 1 << 30, 2_000_000, arrival);
    j.slo = slo;
    j
}

#[test]
fn off_policy_is_byte_identical_to_no_admission_at_event_granularity() {
    // `--admit off` must take the exact ungoverned code paths: same
    // fired-event stream byte for byte, no admission counters, no
    // admission event kinds. (golden_trace.rs additionally pins the
    // off path to the committed fixtures; this is the direct A/B.)
    let mut jobs = Workload::by_id("W1").unwrap().jobs(7);
    poisson_arrivals(&mut jobs, 1.0, 7);
    let (a, ta) = run_cluster_traced(cfg(None), jobs.clone());
    let off = Some(AdmissionConfig { policy: "off", ..Default::default() });
    let (b, tb) = run_cluster_traced(cfg(off), jobs);
    assert_eq!(ta, tb, "off policy must replay the ungoverned stream exactly");
    assert_eq!(a.makespan, b.makespan);
    assert_eq!((b.rejected, b.degraded), (0, 0));
    for (x, y) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!((x.started, x.ended, x.node), (y.started, y.ended, y.node));
    }
}

#[test]
fn a_bucket_refilled_at_exactly_the_arrival_rate_admits_everything() {
    // The boundary case: 1 token/s refill, depth 1, batch arrivals
    // spaced at exactly 1 s. Every arrival finds exactly one token —
    // any off-by-one in the refill arithmetic (refill-after-spend,
    // strict instead of >= comparison) would shed work the configured
    // rate can afford.
    let jobs: Vec<JobSpec> = (0..12)
        .map(|i| job(&format!("b{i}"), Some(SloClass::Batch), i as f64))
        .collect();
    let r = run_cluster(cfg(token(1.0, 1.0)), jobs);
    assert_eq!((r.rejected, r.degraded), (0, 0), "exactly-capacity load sheds nothing");
    assert_eq!(r.completed(), 12);
}

#[test]
fn an_overdriven_burst_sheds_strictly_by_class() {
    // A same-instant burst against a depth-2 bucket with negligible
    // refill: the two batch arrivals drain the bucket, both best-effort
    // arrivals are turned away, and the latency-sensitive pair is
    // admitted without ever touching a token (they are protected, not
    // metered).
    let jobs = vec![
        job("ls0", Some(SloClass::LatencySensitive), 0.0),
        job("ls1", Some(SloClass::LatencySensitive), 0.0),
        job("batch0", Some(SloClass::Batch), 0.0),
        job("batch1", Some(SloClass::Batch), 0.0),
        job("be0", Some(SloClass::BestEffort), 0.0),
        job("be1", Some(SloClass::BestEffort), 0.0),
    ];
    let r = run_cluster(cfg(token(1e-6, 2.0)), jobs);
    assert_eq!((r.rejected, r.degraded), (2, 0));
    for j in &r.jobs {
        match j.slo {
            Some(SloClass::BestEffort) => assert!(j.rejected, "{} must be shed", j.name),
            _ => assert!(!j.rejected, "{} must be admitted", j.name),
        }
    }
    assert_eq!(r.completed(), 4, "every admitted job still completes");
}

#[test]
fn pressured_batch_degrades_to_best_effort_instead_of_rejecting() {
    // Depth-1 bucket, three same-instant arrivals: the first batch job
    // takes the token, the second finds the bucket empty and is demoted
    // one class (visible in its outcome's SLO), the best-effort job is
    // shed outright.
    let jobs = vec![
        job("batch0", Some(SloClass::Batch), 0.0),
        job("batch1", Some(SloClass::Batch), 0.0),
        job("be0", Some(SloClass::BestEffort), 0.0),
    ];
    let r = run_cluster(cfg(token(1e-6, 1.0)), jobs);
    assert_eq!((r.rejected, r.degraded), (1, 1));
    assert_eq!(r.jobs[0].slo, Some(SloClass::Batch), "token holder keeps its class");
    assert_eq!(r.jobs[1].slo, Some(SloClass::BestEffort), "demotion is recorded");
    assert!(!r.jobs[1].rejected, "degraded jobs still run");
    assert!(r.jobs[2].rejected);
    assert_eq!(r.completed(), 2);
}

#[test]
fn rejected_jobs_hold_no_worker_reservation_or_execution_state() {
    // Over-drive a depth-1 bucket so every best-effort arrival is shed,
    // then check the terminal shape of each rejection — ended at its
    // own arrival instant, zero kernels, zero dedicated seconds — and
    // conservation: admitted + crashed + rejected covers the batch.
    let mut jobs = vec![
        job("ls", Some(SloClass::LatencySensitive), 0.0),
        job("batch", Some(SloClass::Batch), 0.0), // takes the only token
    ];
    for i in 0..6 {
        jobs.push(job(&format!("be{i}"), Some(SloClass::BestEffort), 0.25 * i as f64));
    }
    let r = run_cluster(cfg(token(1e-6, 1.0)), jobs.clone());
    assert_eq!(r.rejected, 6);
    assert_eq!(
        r.completed() + r.crashed() + r.rejected as usize,
        r.jobs.len(),
        "every job reaches exactly one terminal state"
    );
    for j in r.jobs.iter().filter(|j| j.rejected) {
        assert_eq!(j.ended, j.arrival, "{}: terminal at its own arrival instant", j.name);
        assert_eq!(j.n_kernels, 0, "{}: never launched a kernel", j.name);
        assert_eq!(j.kernel_dedicated_s, 0.0);
        assert_eq!(j.preemptions, 0, "{}: never preempted (never ran)", j.name);
    }
    // The stronger form of "holds nothing": re-run with the shed
    // arrivals removed from the workload entirely. If a rejected job
    // ever held a worker, a reservation, or frontend service time, the
    // admitted jobs' timelines would shift; they must be unchanged.
    let admitted: Vec<JobSpec> = jobs
        .iter()
        .zip(&r.jobs)
        .filter(|(_, o)| !o.rejected)
        .map(|(s, _)| s.clone())
        .collect();
    let b = run_cluster(cfg(None), admitted);
    assert_eq!(b.jobs.len(), 2);
    for (x, y) in r.jobs.iter().filter(|j| !j.rejected).zip(&b.jobs) {
        assert_eq!(x.name, y.name);
        assert_eq!(
            (x.started, x.ended, x.node),
            (y.started, y.ended, y.node),
            "{}: timeline must not depend on the shed arrivals",
            x.name
        );
    }
}
